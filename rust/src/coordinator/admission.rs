//! Deadline-aware async admission queue for the batched query path.
//!
//! The paper's ICU use case prioritizes latency over throughput, but after
//! the batched pipeline landed, the cluster only saw a batch when a single
//! caller handed [`Orchestrator::query_batch`] a pre-formed block —
//! concurrent ICU monitors each paid the full per-dispatch cost and never
//! shared a scan. This module is the admission layer that coalesces
//! *independent* callers into batches under a latency budget:
//!
//! * Callers [`submit`](AdmissionQueue::submit) one query plus a latency
//!   budget (and a scheduling [`Class`], via
//!   [`submit_class`](AdmissionQueue::submit_class); a full per-request
//!   operating point — probes, comparison cap, policy, k — via
//!   [`submit_spec`](AdmissionQueue::submit_spec) and a [`QuerySpec`])
//!   and get a [`Ticket`] back; [`Ticket::wait`] blocks on a per-request
//!   one-shot completion slot ([`completion_slot`]) — the reply path is
//!   lock-free (atomic state + `thread::park`, no mutex).
//! * Pending requests live in **two scheduling lanes**:
//!   [`Class::Monitor`] (strict priority, deadline-ordered — the paper's
//!   bedside monitors) and [`Class::Analytics`] (FIFO behind monitors).
//!   A cut takes due-or-aged analytics first, then monitors by earliest
//!   deadline, then fresh analytics; an analytics request that has waited
//!   [`AdmissionConfig::age_bound`] is *promoted* — it rides the very next
//!   cut ([`CutReason::Aged`]) — so sustained monitor traffic can delay
//!   analytics by at most the aging bound, never starve it.
//! * A dedicated **cutter** thread watches the lanes and cuts a batch
//!   when `max_batch` requests are pending ([`CutReason::Fill`]) **or**
//!   the earliest pending effective deadline expires
//!   ([`CutReason::Deadline`] / [`CutReason::Aged`]) — whichever comes
//!   first. A deadline cut always takes *every* pending request (pending
//!   < `max_batch`, else it would have fill-cut), so the most urgent
//!   request is always in the batch it triggers.
//! * **Pipelined dispatch**: the cutter never runs a dispatch itself. It
//!   hands each cut to a dispatcher thread over a bounded channel sized
//!   by [`AdmissionConfig::pipeline`] (default 2 batches in flight), so
//!   cut N+1 is *formed* while cut N is still in the reducer — a tight
//!   deadline arriving mid-dispatch is cut at its deadline, not up to one
//!   batch service time late (the PR 2 failure mode this replaces). When
//!   the window is already full the cutter parks at the handoff, so
//!   under *saturation* a newly due cut can still wait for a pipeline
//!   slot — bounded by the window, where the PR 2 design added the same
//!   delay on every in-flight batch even when idle slots existed.
//! * The queue is bounded: when `queue_cap` requests are pending,
//!   [`submit`](AdmissionQueue::submit) blocks and
//!   [`try_submit`](AdmissionQueue::try_submit) returns
//!   [`AdmissionError::QueueFull`] — backpressure, never silent drops.
//! * Shutdown (dropping the queue) drains: every in-flight request is
//!   dispatched in [`CutReason::Drain`] cuts before the cutter exits, so
//!   no ticket is ever left hanging.
//!
//! Dispatch rides [`Orchestrator::query_batch`]'s flat-block path, so a
//! coalesced batch reuses the per-core `QueryScratch`/`BatchOutput` arenas
//! downstream exactly like a caller-formed block, and the cut's [`Budget`]
//! travels with it together with the batch's class (the TCP wire ships
//! budget, policy and class in a `QueryBatchBudget` frame so remote nodes
//! honor the same cut and attribute overruns per class).
//!
//! **Determinism.** The cutter never reads the wall clock directly: it
//! takes a [`Clock`] (real [`SystemClock`] or test [`MockClock`]), and the
//! optional per-request deadline jitter (used to de-synchronize fleets of
//! periodic monitors) draws from an RNG seeded by
//! [`AdmissionConfig::seed`] — every batching decision is a pure function
//! of (submission order, classes, clock readings, seed), reproducible in
//! tests with no sleeps. Observability is shared with the rest of the
//! serving stack: queue depth through [`QueueStats`] (aggregate and per
//! lane), the cut-reason mix through [`CutCounters`], and per-class
//! dispatch/overrun attribution through [`LaneCounters`], all defined in
//! [`crate::runtime::service`].
//!
//! **Budget enforcement at the nodes.** A cut's remaining budget is
//! computed ONCE, when the dispatcher picks the cut up — time spent
//! queued behind the pipeline counts against it — and shipped to every
//! node together with the queue's [`BudgetPolicy`]
//! ([`AdmissionConfig::budget_policy`]), so in-process and remote nodes
//! enforce against the same deadline, anchored at batch arrival:
//!
//! | policy                           | node behavior on a budget-carrying cut |
//! |----------------------------------|----------------------------------------|
//! | [`BudgetPolicy::LogOnly`]        | full scan always; overruns logged + counted (bit-identical results to a cluster without enforcement) |
//! | [`BudgetPolicy::PartialResults`] | deadline-checked scan at table/tile granularity; once blown, remaining tables are skipped and the reply is flagged `partial` |
//! | [`BudgetPolicy::Shed`]           | budget already spent on node arrival ⇒ reject before ANY scan work (empty reply flagged `shed` + `partial`); otherwise `PartialResults` semantics |
//!
//! **Partial-result semantics.** A partial answer is built from *strict
//! prefixes*, never samples: each core stops after a prefix of its owned
//! tables (and a prefix of the last table's candidate tiles), so every
//! neighbor returned carries its true distance and appears in the
//! unenforced candidate walk; what a node (and then the cluster) returns
//! is the union of those per-core prefixes. The Reducer merges per-node
//! answers as usual and marks the merged [`QueryResult`] `partial` if
//! ANY node answered partially (with `shed_nodes` counting outright
//! rejections), so callers always learn when recall was traded for the
//! deadline — the flag rides the [`Ticket`] unchanged. What `Shed`
//! guarantees: a node never spends scan time on a batch that already
//! missed its deadline, so a backlogged cluster stops burning work on
//! answers nobody can use — the paper's latency-first stance made an
//! enforced contract.
//!
//! **The deadline is per CUT, not per request.** A cut ships ONE
//! remaining budget — that of its most urgent request — so a loose-budget
//! request co-batched with a nearly-expired one inherits the tight
//! deadline and can come back flagged partial (or shed) with plenty of
//! its own budget left. That is the deliberate price of sharing a scan:
//! the batch resolves as a unit, the flag makes the trade visible per
//! result, and the two-lane scheduler already keeps the lanes apart
//! except for fill leftovers and aged promotions. Workloads that cannot
//! accept it should keep enforcement on `LogOnly` or stop co-batching
//! (smaller `max_batch`).
//!
//! **Budgets remain scheduling targets, not hard real-time guarantees.**
//! With a free pipeline slot, a request is *cut* no later than its
//! effective deadline (plus scheduler wakeup); under saturation the cut
//! additionally waits for a slot (see above), and under `LogOnly` the
//! cluster may take longer than the remaining budget to resolve the
//! batch. Misses stay first-class signals: the dispatcher counts every
//! request that resolves past its deadline per class
//! ([`LaneCounters::overruns`]), every partial/shed answer per class
//! ([`LaneCounters::partials`]/[`LaneCounters::sheds`]), and node-side
//! accounting ([`note_batch_overrun`]) logs overruns identically for
//! in-process and remote nodes.
//!
//! **Per-request accuracy knobs.** A [`QuerySpec`] rider also carries its
//! probe count (or `0` = auto), comparison cap, policy escalation and
//! result-k into the queue; at dispatch the cut resolves them batch-wide
//! — widest probes, tightest nonzero cap, strictest policy — and ships a
//! [`ProbeSpec`] alongside the [`Budget`]. The optional [`AutoProbes`]
//! feedback controller tunes each lane's default probe count from live
//! partial/shed signals and a comparisons-per-query EWMA, so auto riders
//! get the widest scan the cluster currently serves inside its budgets.
//!
//! This queue is the architectural seam later scheduling work (e.g. NUMA
//! pinning) plugs into: such features change *which* requests a cut takes
//! or how a node resolves it, not how callers submit or wait.
//!
//! [`QueryResult`]: crate::coordinator::orchestrator::QueryResult
//!
//! [`Orchestrator::query_batch`]: crate::coordinator::Orchestrator::query_batch

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{channel, sync_channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::orchestrator::{ClusterError, QueryResult, QuerySpec};
use crate::lsh::probe::ProbeSpec;
use crate::runtime::service::{CutCounters, LaneCounters, QueueStats};
use crate::runtime::trace::Tracer;
use crate::util::rng::Xoshiro256;

// ---------------------------------------------------------------------------
// Scheduling class
// ---------------------------------------------------------------------------

/// Scheduling class of an admitted query — which lane it waits in.
///
/// The paper's ICU deployment is latency-first: a bedside monitor's
/// similarity verdict must land inside its budget even while bulk
/// analytics share the cluster. [`Class::Monitor`] requests are cut with
/// strict priority (deadline-ordered); [`Class::Analytics`] requests ride
/// leftover batch slots FIFO, protected from starvation by
/// [`AdmissionConfig::age_bound`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Class {
    /// Latency-critical, one-query-in-flight callers (ICU monitors).
    Monitor,
    /// Bulk, throughput-oriented callers (re-scoring, backfills).
    Analytics,
}

impl Class {
    /// Wire encoding (stable: `QueryBatchBudget` frames carry it).
    pub fn as_u8(self) -> u8 {
        match self {
            Class::Monitor => 0,
            Class::Analytics => 1,
        }
    }

    /// Inverse of [`as_u8`](Class::as_u8); `None` for unknown bytes
    /// (hostile/corrupt peers).
    pub fn from_u8(v: u8) -> Option<Class> {
        match v {
            0 => Some(Class::Monitor),
            1 => Some(Class::Analytics),
            _ => None,
        }
    }

    /// Lane index for per-class arrays (0 = monitor, 1 = analytics);
    /// mirrors [`trace::LANE_NAMES`](crate::runtime::trace::LANE_NAMES).
    pub fn idx(self) -> usize {
        self.as_u8() as usize
    }
}

impl std::fmt::Display for Class {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Class::Monitor => f.write_str("monitor"),
            Class::Analytics => f.write_str("analytics"),
        }
    }
}

/// Shared node-side budget-overrun accounting: the node cannot un-spend
/// the time, but a serving deployment needs to SEE misses, attributed to
/// the class that suffered them. Used by `LocalNode::query_batch_budget`,
/// which serves both the in-process path and the TCP server path — so
/// local and remote nodes report overruns identically. Returns whether
/// the batch overran its budget.
pub fn note_batch_overrun(
    node_id: usize,
    class: Class,
    budget_us: u64,
    spent: Duration,
    nq: usize,
) -> bool {
    if budget_us == crate::coordinator::orchestrator::NO_BUDGET {
        return false;
    }
    let spent_us = spent.as_micros().min(u64::MAX as u128) as u64;
    if spent_us <= budget_us {
        return false;
    }
    crate::log_info!(
        "node",
        "budget overrun [{class}]: node {node_id} spent {spent_us}us > {budget_us}us for {nq} queries"
    );
    true
}

// ---------------------------------------------------------------------------
// Clock (defined in util::clock; re-exported here where it is consumed)
// ---------------------------------------------------------------------------

pub use crate::util::clock::{Clock, MockClock, SystemClock, TickClock};

// ---------------------------------------------------------------------------
// Budget policy — the node-side enforcement contract
// ---------------------------------------------------------------------------

/// What a node does with the remaining latency budget that ships with
/// every admission cut. Policy travels with the cut (and over the wire in
/// the `QueryBatchBudget` frame), so in-process and remote nodes enforce
/// the same contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BudgetPolicy {
    /// Observe only: full scans always; overruns logged and counted.
    /// Results are bit-identical to a cluster without enforcement.
    LogOnly,
    /// Enforce by early exit: once the budget is blown the node stops
    /// consulting further tables (and further candidate tiles) and
    /// returns what it has, flagged `partial`. A partial answer is a
    /// strict prefix of the full resolution, never a sample.
    PartialResults,
    /// Enforce by rejection: a batch whose budget is already spent when
    /// it reaches the node is shed before ANY scan work — empty replies
    /// flagged `shed` (and `partial`). A batch that still has budget on
    /// arrival is served with `PartialResults` semantics.
    Shed,
}

impl BudgetPolicy {
    /// Wire encoding (stable: `QueryBatchBudget` frames carry it).
    pub fn as_u8(self) -> u8 {
        match self {
            BudgetPolicy::LogOnly => 0,
            BudgetPolicy::PartialResults => 1,
            BudgetPolicy::Shed => 2,
        }
    }

    /// Inverse of [`as_u8`](BudgetPolicy::as_u8); `None` for unknown
    /// bytes (hostile/corrupt peers must not silently change enforcement
    /// behavior).
    pub fn from_u8(v: u8) -> Option<BudgetPolicy> {
        match v {
            0 => Some(BudgetPolicy::LogOnly),
            1 => Some(BudgetPolicy::PartialResults),
            2 => Some(BudgetPolicy::Shed),
            _ => None,
        }
    }
}

impl std::fmt::Display for BudgetPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetPolicy::LogOnly => f.write_str("log-only"),
            BudgetPolicy::PartialResults => f.write_str("partial-results"),
            BudgetPolicy::Shed => f.write_str("shed"),
        }
    }
}

/// A cut's budget as shipped to every node: the remaining latency budget
/// — computed ONCE, when the dispatcher picks the cut up, so time spent
/// queued in the pipeline counts against it and local and remote nodes
/// enforce against the same deadline — plus the enforcement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Budget {
    /// µs until the batch's most urgent deadline at dispatch, saturating
    /// to 0 once the deadline has passed;
    /// [`NO_BUDGET`](crate::coordinator::orchestrator::NO_BUDGET) when
    /// the batch carries no deadline (caller-formed blocks).
    pub remaining_us: u64,
    pub policy: BudgetPolicy,
}

impl Budget {
    /// An enforced budget under `policy`.
    pub fn enforced(remaining_us: u64, policy: BudgetPolicy) -> Budget {
        Budget { remaining_us, policy }
    }

    /// The no-deadline sentinel (caller-formed bulk blocks): nodes run
    /// plain full scans whatever the policy says.
    pub fn none() -> Budget {
        Budget {
            remaining_us: crate::coordinator::orchestrator::NO_BUDGET,
            policy: BudgetPolicy::LogOnly,
        }
    }

    /// True when this batch carries no deadline at all.
    pub fn is_none(&self) -> bool {
        self.remaining_us == crate::coordinator::orchestrator::NO_BUDGET
    }
}

// ---------------------------------------------------------------------------
// One-shot completion slot (the lock-free reply path)
// ---------------------------------------------------------------------------

const SLOT_EMPTY: u8 = 0;
const SLOT_WAITING: u8 = 1;
const SLOT_FULL: u8 = 2;
const SLOT_CLOSED: u8 = 3;

struct OneShot<T> {
    state: AtomicU8,
    value: UnsafeCell<Option<T>>,
    waiter: UnsafeCell<Option<std::thread::Thread>>,
}

// SAFETY: the cells are only touched under the state-machine protocol
// below — `value` is written by the single writer before the Release
// transition to FULL and read by the single reader after an Acquire load
// of FULL; `waiter` is written by the single reader before its Release
// CAS to WAITING and read by the single writer only after an Acquire
// observation of WAITING. `SlotWriter`/`SlotReader` are not Clone and
// their operations consume `self`, so single-writer/single-reader holds
// in safe code.
unsafe impl<T: Send> Send for OneShot<T> {}
unsafe impl<T: Send> Sync for OneShot<T> {}

/// Producer half of a one-shot completion slot.
pub struct SlotWriter<T>(Arc<OneShot<T>>);

/// Consumer half of a one-shot completion slot.
pub struct SlotReader<T>(Arc<OneShot<T>>);

/// A single-producer single-consumer, one-shot, lock-free handoff cell:
/// `fulfill` publishes a value with one atomic swap; `wait` parks the
/// calling thread until the value (or a writer-dropped signal) arrives.
/// This is the admission queue's reply path — no mutex is ever taken
/// between the cutter finishing a batch and a caller waking up.
pub fn completion_slot<T: Send>() -> (SlotWriter<T>, SlotReader<T>) {
    let shared = Arc::new(OneShot {
        state: AtomicU8::new(SLOT_EMPTY),
        value: UnsafeCell::new(None),
        waiter: UnsafeCell::new(None),
    });
    (SlotWriter(Arc::clone(&shared)), SlotReader(shared))
}

impl<T: Send> SlotWriter<T> {
    /// Publish the value and wake the reader (if it is already parked).
    pub fn fulfill(self, v: T) {
        let s = &self.0;
        // SAFETY: single writer, and the reader cannot touch `value`
        // until it observes FULL (published by the swap below).
        unsafe { *s.value.get() = Some(v) };
        let prev = s.state.swap(SLOT_FULL, Ordering::AcqRel);
        debug_assert!(prev == SLOT_EMPTY || prev == SLOT_WAITING, "one-shot fulfilled twice");
        if prev == SLOT_WAITING {
            // SAFETY: the reader wrote `waiter` before its Release CAS to
            // WAITING, which we just Acquire-observed; it will not write
            // again.
            if let Some(t) = unsafe { (*s.waiter.get()).take() } {
                t.unpark();
            }
        }
        // Drop of `self` sees FULL and leaves the cell alone.
    }
}

impl<T> Drop for SlotWriter<T> {
    fn drop(&mut self) {
        // Writer going away without fulfilling: close the slot so the
        // reader unblocks with `None` instead of hanging forever.
        let s = &self.0;
        let mut cur = s.state.load(Ordering::Acquire);
        loop {
            if cur == SLOT_FULL || cur == SLOT_CLOSED {
                return;
            }
            match s.state.compare_exchange(cur, SLOT_CLOSED, Ordering::AcqRel, Ordering::Acquire)
            {
                Ok(_) => {
                    if cur == SLOT_WAITING {
                        // SAFETY: same visibility argument as in `fulfill`.
                        if let Some(t) = unsafe { (*s.waiter.get()).take() } {
                            t.unpark();
                        }
                    }
                    return;
                }
                Err(actual) => cur = actual,
            }
        }
    }
}

impl<T: Send> SlotReader<T> {
    /// Block until the writer fulfills the slot (`Some`) or drops without
    /// fulfilling it (`None`).
    pub fn wait(self) -> Option<T> {
        let s = &self.0;
        let mut cur = s.state.load(Ordering::Acquire);
        if cur == SLOT_EMPTY {
            // Register for wakeup, then re-check: the writer may have
            // raced past between the load and the CAS.
            // SAFETY: single reader; the writer only reads `waiter` after
            // observing WAITING, which this CAS publishes.
            unsafe { *s.waiter.get() = Some(std::thread::current()) };
            match s.state.compare_exchange(
                SLOT_EMPTY,
                SLOT_WAITING,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => loop {
                    cur = s.state.load(Ordering::Acquire);
                    if cur == SLOT_FULL || cur == SLOT_CLOSED {
                        break;
                    }
                    std::thread::park();
                },
                Err(actual) => cur = actual,
            }
        }
        match cur {
            // SAFETY: FULL was published after the writer's value store.
            SLOT_FULL => unsafe { (*s.value.get()).take() },
            SLOT_CLOSED => None,
            _ => unreachable!("one-shot left in transient state"),
        }
    }
}

// ---------------------------------------------------------------------------
// Admission queue
// ---------------------------------------------------------------------------

/// Admission-layer configuration.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Query dimensionality (every submission is checked against it —
    /// a ragged batch flattened as-if-rectangular would scan garbage).
    pub dim: usize,
    /// Cut a batch as soon as this many requests are pending.
    pub max_batch: usize,
    /// Bounded-queue capacity; beyond it, `submit` blocks (backpressure).
    pub queue_cap: usize,
    /// Optional deadline jitter as a fraction of the budget (e.g. `0.1`
    /// spreads each deadline ±10%) — de-synchronizes fleets of periodic
    /// monitors so their cuts don't stampede. `0.0` disables it.
    pub budget_jitter: f64,
    /// Seed for the jitter RNG; batching decisions are reproducible from
    /// (submission order, clock, seed).
    pub seed: u64,
    /// Anti-starvation bound for the analytics lane: an analytics request
    /// that has been pending this long is promoted into the very next cut
    /// ahead of monitors, and fires an [`CutReason::Aged`] cut of its own
    /// if no other trigger arrives first. Under sustained monitor load,
    /// analytics dispatch latency is therefore bounded by `age_bound`
    /// plus one pipeline slot, never unbounded.
    pub age_bound: Duration,
    /// Dispatch pipeline depth: how many cuts may be in flight downstream
    /// of the cutter (the one being dispatched plus those queued for the
    /// dispatcher). With `pipeline >= 2` the cutter forms cut N+1 while
    /// cut N is still in the reducer; `1` degenerates to a rendezvous
    /// handoff (the cutter still never blocks *inside* a dispatch).
    pub pipeline: usize,
    /// Node-side budget enforcement policy shipped with every cut (see
    /// [`BudgetPolicy`]). Defaults to [`BudgetPolicy::LogOnly`], which is
    /// bit-identical to a cluster without enforcement. A rider whose
    /// [`QuerySpec`] names a stricter policy escalates the whole cut (the
    /// config is the floor, never the ceiling).
    pub budget_policy: BudgetPolicy,
    /// Optional per-lane probe-count feedback controller (see
    /// [`AutoProbes`]). `None` (the default) pins auto-probe riders to 1
    /// probe — the legacy single-bucket scan.
    pub auto_probes: Option<AutoProbes>,
}

/// Feedback controller for the per-lane *default* probe count — the value
/// auto-probe riders (a [`QuerySpec`] with `probes == 0` and no
/// `recall_hint`) inherit at cut time. After every dispatched cut the
/// controller folds the observed comparisons-per-query into a lane EWMA
/// (`ewma = round((7·prev + obs) / 8)`, saturating — see [`ewma_fold`])
/// and steps the lane's probe count by ±1:
/// down when the cut came back stressed (any partial or shed rider on the
/// lane) or the EWMA exceeds `target_comparisons`, up otherwise — a
/// classic AIAD walk that converges onto the widest probe count the
/// cluster can serve inside its budgets. Explicit `probes`/`recall_hint`
/// riders bypass the controller entirely; the EWMA telemetry is kept even
/// when the controller is off (surfaced via [`LaneStats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoProbes {
    /// Floor for the lane probe count (also its starting value); >= 1.
    pub min: u32,
    /// Ceiling for the lane probe count; >= `min`.
    pub max: u32,
    /// Comparisons-per-query EWMA above which the lane steps down even
    /// without enforcement stress — the operator's cost budget.
    pub target_comparisons: u64,
}

/// One EWMA step, `round((7·prev + obs) / 8)`, in u128 so `7 · prev`
/// cannot wrap for any `u64` input, saturating back to `u64::MAX`.
/// Round-to-nearest (the `+ 4` before the divide) instead of truncation:
/// truncation biases every step toward zero, which can pin the EWMA at a
/// stale floor below a constant observation (e.g. prev = 16, obs = 23
/// truncates to 16 forever; rounding walks up to within 3).
#[inline]
fn ewma_fold(prev: u64, obs: u64) -> u64 {
    ((7u128 * u128::from(prev) + u128::from(obs) + 4) / 8).min(u128::from(u64::MAX)) as u64
}

impl AdmissionConfig {
    pub fn new(dim: usize, max_batch: usize) -> AdmissionConfig {
        AdmissionConfig {
            dim,
            max_batch,
            queue_cap: 1024,
            budget_jitter: 0.0,
            seed: 0,
            age_bound: Duration::from_millis(25),
            pipeline: 2,
            budget_policy: BudgetPolicy::LogOnly,
            auto_probes: None,
        }
    }

    pub fn with_queue_cap(mut self, cap: usize) -> AdmissionConfig {
        self.queue_cap = cap;
        self
    }

    pub fn with_jitter(mut self, frac: f64, seed: u64) -> AdmissionConfig {
        self.budget_jitter = frac;
        self.seed = seed;
        self
    }

    pub fn with_age_bound(mut self, bound: Duration) -> AdmissionConfig {
        self.age_bound = bound;
        self
    }

    pub fn with_pipeline(mut self, depth: usize) -> AdmissionConfig {
        self.pipeline = depth;
        self
    }

    pub fn with_budget_policy(mut self, policy: BudgetPolicy) -> AdmissionConfig {
        self.budget_policy = policy;
        self
    }

    /// Enable the per-lane probe-count feedback controller.
    pub fn with_auto_probes(mut self, auto: AutoProbes) -> AdmissionConfig {
        self.auto_probes = Some(auto);
        self
    }
}

/// Admission-layer errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// Bounded queue at capacity (only from [`AdmissionQueue::try_submit`];
    /// the blocking [`AdmissionQueue::submit`] waits instead).
    QueueFull,
    /// The queue is shutting down; the request was not admitted.
    ShuttingDown,
    /// The request was admitted but the dispatcher died before resolving
    /// it (only during teardown of the underlying cluster).
    Canceled,
    /// The request was admitted and dispatched, but the cluster failed it
    /// (see [`ClusterError`]) — the typed replacement for the old
    /// panic-on-dead-cluster path: callers get the error through their
    /// [`Ticket`] instead of a poisoned process.
    Cluster(ClusterError),
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull => write!(f, "admission queue full"),
            AdmissionError::ShuttingDown => write!(f, "admission queue shutting down"),
            AdmissionError::Canceled => write!(f, "request canceled during teardown"),
            AdmissionError::Cluster(e) => write!(f, "cluster failed the batch: {e}"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Why the cutter dispatched a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CutReason {
    /// `max_batch` requests were pending.
    Fill,
    /// The earliest pending deadline expired.
    Deadline,
    /// An analytics request hit the anti-starvation aging bound before
    /// any real deadline or fill trigger.
    Aged,
    /// Shutdown drained the residue.
    Drain,
}

/// A caller's handle to one submitted query.
#[must_use = "dropping a Ticket discards the query result"]
pub struct Ticket {
    reader: SlotReader<Result<QueryResult, AdmissionError>>,
}

impl Ticket {
    /// Block until the batch containing this request has been resolved.
    pub fn wait(self) -> Result<QueryResult, AdmissionError> {
        self.reader.wait().unwrap_or(Err(AdmissionError::Canceled))
    }
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Ticket(..)")
    }
}

/// Per-lane counter snapshot (see [`AdmissionQueue::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LaneStats {
    /// Requests of this class currently pending.
    pub depth: usize,
    /// Maximum pending depth ever observed for this class.
    pub high_water: usize,
    /// Total requests of this class admitted.
    pub submitted: u64,
    /// Requests of this class dispatched via fill cuts.
    pub dispatched_fill: u64,
    /// Requests of this class dispatched via deadline cuts.
    pub dispatched_deadline: u64,
    /// Requests of this class dispatched via aged (anti-starvation) cuts.
    pub dispatched_aged: u64,
    /// Requests of this class dispatched via shutdown drain cuts.
    pub dispatched_drain: u64,
    /// Requests of this class whose batch resolved after their deadline.
    pub overruns: u64,
    /// Requests of this class answered from an incomplete scan (at least
    /// one node returned a budget-enforced partial answer; includes
    /// sheds).
    pub partials: u64,
    /// Requests of this class where at least one node shed the batch
    /// outright (zero scan work) under [`BudgetPolicy::Shed`].
    pub sheds: u64,
    /// Points ingested (online inserts) attributed to this class.
    pub inserted: u64,
    /// `try_submit` rejections of this class due to a full queue.
    pub rejected_full: u64,
    /// Current per-lane default probe count — what auto-probe riders of
    /// this class inherit at cut time (1 unless [`AutoProbes`] moved it).
    pub probes: u32,
    /// EWMA of observed comparisons-per-query on this lane's cuts (0
    /// until the first cut resolves) — the controller's feedback signal,
    /// exported even when the controller is off.
    pub ewma_comparisons: u64,
}

/// Counter snapshot (see [`AdmissionQueue::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Requests currently pending (admitted, not yet cut), both lanes.
    pub depth: usize,
    /// Maximum pending depth ever observed (both lanes combined).
    pub high_water: usize,
    /// Total requests admitted.
    pub submitted: u64,
    /// Total requests taken into a dispatched batch.
    pub completed: u64,
    /// `try_submit` rejections due to a full queue.
    pub rejected_full: u64,
    pub cuts_fill: u64,
    pub cuts_deadline: u64,
    pub cuts_aged: u64,
    pub cuts_drain: u64,
    /// Whether the [`AutoProbes`] feedback controller is enabled.
    pub auto_probes: bool,
    /// Monitor-lane breakdown.
    pub monitor: LaneStats,
    /// Analytics-lane breakdown.
    pub analytics: LaneStats,
}

struct Pending {
    q: Vec<f32>,
    class: Class,
    /// When the request was admitted (clock ns) — the aging origin.
    enqueue_ns: u64,
    /// `u64::MAX` = budgetless (a [`QuerySpec`] without a budget): never
    /// deadline-cuts; rides fill/aged/drain cuts.
    deadline_ns: u64,
    /// Requested probes per outer table; 0 = auto (inherit the lane's
    /// feedback-controlled default at cut time).
    probes: u32,
    /// Candidate-budget cap (0 = unlimited); the cut takes the tightest
    /// nonzero cap across its riders.
    max_comparisons: u64,
    /// Per-request policy escalation; the cut folds these with the
    /// configured [`AdmissionConfig::budget_policy`] as the floor.
    policy: Option<BudgetPolicy>,
    /// Truncate the rider's returned neighbor list to this length at
    /// fulfillment (0 = cluster default K).
    k: usize,
    /// Trace id minted at admission (0 = untraced queue). Stamped on the
    /// rider's queue-wait / service spans at dispatch and carried to the
    /// cut's wire frame so worker scan spans join the same trace.
    trace: u64,
    slot: SlotWriter<Result<QueryResult, AdmissionError>>,
}

struct State {
    /// Strict-priority lane, cut in deadline order.
    monitors: VecDeque<Pending>,
    /// Best-effort lane, FIFO, promoted after `age_bound`.
    analytics: VecDeque<Pending>,
    shutdown: bool,
    jitter_rng: Xoshiro256,
}

impl State {
    fn len(&self) -> usize {
        self.monitors.len() + self.analytics.len()
    }

    fn is_empty(&self) -> bool {
        self.monitors.is_empty() && self.analytics.is_empty()
    }
}

struct Shared {
    state: Mutex<State>,
    /// Wakes the cutter: new submission or shutdown.
    cutter_wake: Condvar,
    /// Wakes blocked submitters: a cut freed queue space (or shutdown).
    space_free: Condvar,
    clock: Arc<dyn Clock>,
    queue: Arc<QueueStats>,
    cuts: Arc<CutCounters>,
    /// Per-class depth gauges, indexed by `Class::idx()`.
    lane_queue: [Arc<QueueStats>; 2],
    /// Per-class dispatch/overrun counters, indexed by `Class::idx()`.
    lane_counters: [Arc<LaneCounters>; 2],
    /// Per-class default probe count auto-probe riders inherit at cut
    /// time, indexed by `Class::idx()` (stepped by [`AutoProbes`]).
    lane_probes: [AtomicU32; 2],
    /// Per-class EWMA of comparisons-per-query, indexed by `Class::idx()`.
    lane_ewma: [AtomicU64; 2],
    /// Observability sink ([`AdmissionQueue::start_traced`]): mints a
    /// trace id per rider and receives per-rider queue-wait / service /
    /// e2e spans and histograms at dispatch. `None` on the plain
    /// constructors — the hot path then pays nothing beyond the clock
    /// reads it already made.
    tracer: Option<Arc<Tracer>>,
    cfg: AdmissionConfig,
}

/// One cut on its way from the cutter to the dispatcher.
struct CutJob {
    batch: Vec<Pending>,
}

/// The admission queue: two bounded scheduling lanes + deadline-aware
/// cutter thread + pipelined dispatcher thread. See the
/// [module docs](self) for the full contract.
pub struct AdmissionQueue {
    shared: Arc<Shared>,
    cutter: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
}

/// Effective budget in nanoseconds after jitter. Pure so tests can prove
/// reproducibility: the same seed yields the same deadline stream.
fn jittered_budget_ns(budget: Duration, jitter_frac: f64, rng: &mut Xoshiro256) -> u64 {
    let base = budget.as_nanos().min(u64::MAX as u128) as u64;
    if jitter_frac <= 0.0 {
        return base;
    }
    let f = rng.gen_f64(-jitter_frac, jitter_frac);
    let delta = (base as f64 * f) as i64;
    if delta >= 0 {
        base.saturating_add(delta as u64)
    } else {
        base.saturating_sub(delta.unsigned_abs())
    }
}

/// A pending request's *effective* deadline: the instant at which the
/// cutter must ship it. For monitors that is the real budget deadline;
/// for analytics it is the earlier of the budget deadline and the
/// anti-starvation promotion instant (`enqueue + age_bound`). The bool
/// is `true` when the promotion instant is the binding one — that is
/// what makes a triggered cut [`CutReason::Aged`] vs
/// [`CutReason::Deadline`].
fn effective_deadline_ns(p: &Pending, age_bound_ns: u64) -> (u64, bool) {
    match p.class {
        Class::Monitor => (p.deadline_ns, false),
        Class::Analytics => {
            let promo = p.enqueue_ns.saturating_add(age_bound_ns);
            if promo < p.deadline_ns {
                (promo, true)
            } else {
                (p.deadline_ns, false)
            }
        }
    }
}

/// Earliest effective deadline across both lanes — what the cutter
/// sleeps toward.
fn earliest_effective_ns(st: &State, age_bound_ns: u64) -> Option<u64> {
    st.monitors
        .iter()
        .chain(st.analytics.iter())
        .map(|p| effective_deadline_ns(p, age_bound_ns).0)
        .min()
}

/// The cut decision — a pure function of (lane state, `max_batch`,
/// `age_bound`, now). `None` means keep waiting.
///
/// **Trigger.** Fill when both lanes together hold `max_batch`; drain
/// under shutdown; otherwise the *earliest effective deadline* across
/// both lanes (not merely a lane front: a tight budget submitted behind
/// a loose one must still be honored). Since `pending < max_batch`
/// whenever a deadline/aged cut fires, it takes the whole queue and the
/// urgent request always rides the cut it triggered. A cut whose trigger
/// was an analytics promotion is reported as [`CutReason::Aged`]; ties
/// with a real deadline report [`CutReason::Deadline`].
///
/// **Composition** (matters only when `pending > max_batch`): ONE slot
/// goes to the oldest due-or-aged analytics request, if any (the
/// anti-starvation bound must hold even under fill pressure, but it is
/// capped at one slot per cut so an aged-analytics *backlog* drains one
/// per cut instead of inverting priority and starving monitors); the
/// rest go to monitors by earliest deadline (stable: equal deadlines
/// keep arrival order), then to fresh analytics FIFO. Batch composition
/// never changes per-query results (reduction is order-invariant; see
/// `rust/tests/admission_parity.rs`) — it changes only who waits.
fn take_cut(
    st: &mut State,
    max_batch: usize,
    age_bound_ns: u64,
    now_ns: u64,
) -> Option<(Vec<Pending>, CutReason)> {
    let total = st.len();
    if total == 0 {
        return None;
    }
    // The full deadline scan is only paid on the not-full path, where
    // `pending < max_batch` bounds it; a fill cut reads at most one
    // effective deadline (the analytics front, in composition step 1).
    let reason = if total >= max_batch {
        CutReason::Fill
    } else if st.shutdown {
        CutReason::Drain
    } else {
        let mut min_dl = u64::MAX;
        let mut min_promoted = false;
        for p in st.monitors.iter().chain(st.analytics.iter()) {
            let (d, promoted) = effective_deadline_ns(p, age_bound_ns);
            if d < min_dl {
                min_dl = d;
                min_promoted = promoted;
            } else if d == min_dl && !promoted {
                min_promoted = false;
            }
        }
        if min_dl > now_ns {
            return None;
        }
        if min_promoted {
            CutReason::Aged
        } else {
            CutReason::Deadline
        }
    };

    let n = total.min(max_batch);
    let mut batch: Vec<Pending> = Vec::with_capacity(n);

    // Whole-queue cut (every deadline/aged/drain cut, and an exactly-full
    // fill cut): composition cannot change membership, so skip the
    // selection machinery — this is the common case and it runs under the
    // state mutex. Order within a batch is cosmetic (results are zipped
    // back by index; the budget is a min over the batch).
    if n == total {
        batch.extend(st.monitors.drain(..));
        batch.extend(st.analytics.drain(..));
        return Some((batch, reason));
    }

    // (1) The oldest due-or-aged analytics request, if any: the
    // starvation bound holds even when monitors could fill the whole
    // batch, but only ONE promoted slot per cut — a deep aged backlog
    // drains one per cut rather than shutting monitors out entirely.
    // FIFO admission means the front of the lane is the oldest, so a
    // front check suffices (no lane scan on the fill path).
    if let Some(front) = st.analytics.front() {
        if effective_deadline_ns(front, age_bound_ns).0 <= now_ns {
            batch.push(st.analytics.pop_front().unwrap());
        }
    }

    // (2) Monitors, earliest deadline first (stable on ties).
    if batch.len() < n && !st.monitors.is_empty() {
        let take = (n - batch.len()).min(st.monitors.len());
        let mut all: Vec<(usize, Pending)> = st.monitors.drain(..).enumerate().collect();
        all.sort_by_key(|(i, p)| (p.deadline_ns, *i));
        let mut rest = all.split_off(take);
        batch.extend(all.into_iter().map(|(_, p)| p));
        // Put the leftovers back in arrival order.
        rest.sort_by_key(|(i, _)| *i);
        st.monitors.extend(rest.into_iter().map(|(_, p)| p));
    }

    // (3) Fresh analytics, FIFO, into the remaining slots.
    while batch.len() < n {
        batch.push(st.analytics.pop_front().expect("slot accounting: n <= total"));
    }

    Some((batch, reason))
}

impl AdmissionQueue {
    /// Start the queue with the production clock. `dispatch` resolves one
    /// flat row-major block (`nq × dim` floats, plus the cut's [`Budget`]
    /// — the remaining µs of the batch's most urgent request, computed at
    /// dispatch and saturating to 0 once the deadline has passed, paired
    /// with the cut's effective [`BudgetPolicy`] — the batch's scheduling
    /// class: [`Class::Monitor`] if any monitor rides the cut — and the
    /// cut's [`ProbeSpec`]: the widest resolved probe count and tightest
    /// nonzero comparison cap across its riders) and returns exactly `nq`
    /// results in order.
    /// The sixth `dispatch` argument is the cut's wire trace id: the
    /// first rider's trace when a collecting [`Tracer`] is attached
    /// (see [`AdmissionQueue::start_traced`]), `0` otherwise — so an
    /// untraced queue's downstream traffic is byte-identical to one
    /// built before tracing existed.
    pub fn start<D>(cfg: AdmissionConfig, dispatch: D) -> AdmissionQueue
    where
        D: FnMut(
                Vec<f32>,
                usize,
                Budget,
                Class,
                ProbeSpec,
                u64,
            ) -> Result<Vec<QueryResult>, ClusterError>
            + Send
            + 'static,
    {
        AdmissionQueue::start_inner(cfg, dispatch, Arc::new(SystemClock::new()), None)
    }

    /// Start with an injected [`Clock`] (tests use [`MockClock`]).
    pub fn start_with_clock<D>(
        cfg: AdmissionConfig,
        dispatch: D,
        clock: Arc<dyn Clock>,
    ) -> AdmissionQueue
    where
        D: FnMut(
                Vec<f32>,
                usize,
                Budget,
                Class,
                ProbeSpec,
                u64,
            ) -> Result<Vec<QueryResult>, ClusterError>
            + Send
            + 'static,
    {
        AdmissionQueue::start_inner(cfg, dispatch, clock, None)
    }

    /// Start with an attached [`Tracer`] — the queue runs on the
    /// tracer's clock (one clock per trace, so queue-wait and service
    /// spans subtract cleanly), mints a trace id per admitted request,
    /// and records per-rider queue-wait / service / e2e into the
    /// tracer's lane histograms at dispatch. When the tracer is
    /// collecting spans, each rider also gets `queue_wait` and
    /// `service` spans and the cut's first-rider trace id rides the
    /// wire to the workers.
    pub fn start_traced<D>(
        cfg: AdmissionConfig,
        dispatch: D,
        tracer: Arc<Tracer>,
    ) -> AdmissionQueue
    where
        D: FnMut(
                Vec<f32>,
                usize,
                Budget,
                Class,
                ProbeSpec,
                u64,
            ) -> Result<Vec<QueryResult>, ClusterError>
            + Send
            + 'static,
    {
        let clock = tracer.clock();
        AdmissionQueue::start_inner(cfg, dispatch, clock, Some(tracer))
    }

    fn start_inner<D>(
        cfg: AdmissionConfig,
        mut dispatch: D,
        clock: Arc<dyn Clock>,
        tracer: Option<Arc<Tracer>>,
    ) -> AdmissionQueue
    where
        D: FnMut(
                Vec<f32>,
                usize,
                Budget,
                Class,
                ProbeSpec,
                u64,
            ) -> Result<Vec<QueryResult>, ClusterError>
            + Send
            + 'static,
    {
        assert!(cfg.dim > 0, "admission dim must be positive");
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        assert!(cfg.queue_cap > 0, "queue_cap must be positive");
        assert!(cfg.pipeline > 0, "pipeline depth must be positive");
        if let Some(auto) = cfg.auto_probes {
            assert!(auto.min >= 1, "auto_probes.min must be >= 1");
            assert!(auto.max >= auto.min, "auto_probes.max must be >= min");
        }
        let probes0 = cfg.auto_probes.map_or(1, |a| a.min);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                monitors: VecDeque::with_capacity(cfg.queue_cap.min(4096)),
                analytics: VecDeque::with_capacity(cfg.queue_cap.min(4096)),
                shutdown: false,
                jitter_rng: Xoshiro256::seed_from_u64(cfg.seed),
            }),
            cutter_wake: Condvar::new(),
            space_free: Condvar::new(),
            clock,
            queue: Arc::new(QueueStats::new()),
            cuts: Arc::new(CutCounters::new()),
            lane_queue: [Arc::new(QueueStats::new()), Arc::new(QueueStats::new())],
            lane_counters: [Arc::new(LaneCounters::new()), Arc::new(LaneCounters::new())],
            lane_probes: [AtomicU32::new(probes0), AtomicU32::new(probes0)],
            lane_ewma: [AtomicU64::new(0), AtomicU64::new(0)],
            tracer,
            cfg,
        });

        // Pipelined dispatch: the cutter feeds cuts into a bounded
        // channel (`pipeline` batches in flight: one being dispatched
        // plus `pipeline - 1` queued) and keeps cutting — a deadline
        // falling due while a batch is on the cluster fires on time
        // instead of waiting out the dispatch.
        let (cut_tx, cut_rx) = sync_channel::<CutJob>(shared.cfg.pipeline - 1);

        let shared_d = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("admission-dispatch".into())
            .spawn(move || {
                let shared = shared_d;
                while let Ok(CutJob { batch }) = cut_rx.recv() {
                    let nq = batch.len();
                    let start_ns = shared.clock.now_ns();
                    // Remaining budget of the batch's most urgent request,
                    // computed ONCE here — time spent queued behind the
                    // pipeline counts against it, and every node (local or
                    // remote) enforces against this same number. A cut of
                    // entirely budgetless riders (deadline u64::MAX) ships
                    // the no-deadline sentinel instead.
                    let min_deadline =
                        batch.iter().map(|p| p.deadline_ns).min().unwrap_or(u64::MAX);
                    // The strictest rider policy governs the shared cut;
                    // the queue's configured policy is the floor (the
                    // `as_u8` encoding orders LogOnly < Partial < Shed).
                    let policy = batch.iter().filter_map(|p| p.policy).fold(
                        shared.cfg.budget_policy,
                        |acc, p| if p.as_u8() > acc.as_u8() { p } else { acc },
                    );
                    let budget = if min_deadline == u64::MAX {
                        Budget::none()
                    } else {
                        Budget::enforced(min_deadline.saturating_sub(start_ns) / 1_000, policy)
                    };
                    let class = if batch.iter().any(|p| p.class == Class::Monitor) {
                        Class::Monitor
                    } else {
                        Class::Analytics
                    };
                    // Cut-level probe knobs: the WIDEST resolved probe
                    // count (the batch shares one scan, so the widest
                    // request sets it; auto riders inherit their lane's
                    // controller value) and the TIGHTEST nonzero
                    // comparison cap (a cap is a promise to stop, and the
                    // strictest promise must hold for its rider).
                    let mut probes_cut = 1u32;
                    let mut cap_cut = 0u64;
                    for p in &batch {
                        let rp = if p.probes > 0 {
                            p.probes
                        } else {
                            shared.lane_probes[p.class.idx()].load(Ordering::Relaxed)
                        };
                        probes_cut = probes_cut.max(rp.max(1));
                        if p.max_comparisons > 0 {
                            cap_cut = if cap_cut == 0 {
                                p.max_comparisons
                            } else {
                                cap_cut.min(p.max_comparisons)
                            };
                        }
                    }
                    let probe = ProbeSpec::new(probes_cut, cap_cut);
                    let mut flat = Vec::with_capacity(nq * shared.cfg.dim);
                    for p in &batch {
                        flat.extend_from_slice(&p.q);
                    }
                    // The cut's wire trace: the first rider's id, and
                    // only while spans are being collected — an idle
                    // tracer keeps downstream frames byte-identical to
                    // an untraced queue's.
                    let cut_trace = match shared.tracer.as_ref() {
                        Some(t) if t.collecting() => batch.first().map_or(0, |p| p.trace),
                        _ => 0,
                    };
                    let outcome = dispatch(flat, nq, budget, class, probe, cut_trace);
                    // Per-class overrun attribution: every request whose
                    // deadline passed before its batch resolved is a miss
                    // the lane counters must surface.
                    let end_ns = shared.clock.now_ns();
                    let mut overruns = [0u64; 2];
                    for p in &batch {
                        if end_ns > p.deadline_ns {
                            overruns[p.class.idx()] += 1;
                        }
                    }
                    for (idx, n) in overruns.into_iter().enumerate() {
                        if n > 0 {
                            shared.lane_counters[idx].record_overruns(n);
                        }
                    }
                    let results = match outcome {
                        Ok(results) => results,
                        Err(e) => {
                            // The cluster failed the whole batch (e.g. it
                            // was dropped mid-flight): every rider learns
                            // why through its ticket; nothing panics,
                            // nothing hangs. Traces are closed as
                            // shed+partial — a failed request did no scan
                            // work, and an open trace must never leak.
                            if let Some(t) = shared.tracer.as_ref() {
                                for p in &batch {
                                    let e2e_us =
                                        end_ns.saturating_sub(p.enqueue_ns) / 1_000;
                                    t.finish(p.trace, p.class.idx(), e2e_us, true, true);
                                }
                            }
                            for p in batch {
                                p.slot.fulfill(Err(AdmissionError::Cluster(e)));
                            }
                            continue;
                        }
                    };
                    if results.len() == nq {
                        // Per-class partial/shed attribution: enforcement
                        // outcomes are health signals, surfaced on the
                        // same lane counters as overruns.
                        let mut partials = [0u64; 2];
                        let mut sheds = [0u64; 2];
                        for (p, r) in batch.iter().zip(&results) {
                            if r.partial {
                                partials[p.class.idx()] += 1;
                            }
                            if r.shed_nodes > 0 {
                                sheds[p.class.idx()] += 1;
                            }
                        }
                        for idx in 0..2 {
                            if partials[idx] > 0 {
                                shared.lane_counters[idx].record_partials(partials[idx]);
                            }
                            if sheds[idx] > 0 {
                                shared.lane_counters[idx].record_sheds(sheds[idx]);
                            }
                        }
                        // Per-lane comparisons telemetry + auto-probe
                        // feedback: fold the mean comparisons-per-query
                        // into the lane EWMA, then (controller on) step
                        // the lane's default probe count — down under
                        // enforcement stress or past the cost target, up
                        // while comfortably under it.
                        let mut lane_sum = [0u64; 2];
                        let mut lane_n = [0u64; 2];
                        for (p, r) in batch.iter().zip(&results) {
                            lane_sum[p.class.idx()] += r.max_comparisons;
                            lane_n[p.class.idx()] += 1;
                        }
                        for idx in 0..2 {
                            if lane_n[idx] == 0 {
                                continue;
                            }
                            let obs = lane_sum[idx] / lane_n[idx];
                            let prev = shared.lane_ewma[idx].load(Ordering::Relaxed);
                            let ewma = if prev == 0 { obs } else { ewma_fold(prev, obs) };
                            shared.lane_ewma[idx].store(ewma, Ordering::Relaxed);
                            if let Some(auto) = shared.cfg.auto_probes {
                                let cur = shared.lane_probes[idx].load(Ordering::Relaxed);
                                let stressed = partials[idx] > 0 || sheds[idx] > 0;
                                let next = if stressed || ewma > auto.target_comparisons {
                                    cur.saturating_sub(1).max(auto.min)
                                } else {
                                    cur.saturating_add(1).min(auto.max)
                                };
                                shared.lane_probes[idx].store(next, Ordering::Relaxed);
                            }
                        }
                        // Per-rider observability: queue-wait is
                        // enqueue → dispatch-start, service is the shared
                        // batch resolution, e2e their sum — all on the
                        // queue's one clock, so MockClock tests pin every
                        // span exactly. `finish` routes slow / partial /
                        // shed / hedged requests into the slow ring.
                        if let Some(t) = shared.tracer.as_ref() {
                            for (p, r) in batch.iter().zip(&results) {
                                let lane = p.class.idx();
                                let queue_wait_us =
                                    start_ns.saturating_sub(p.enqueue_ns) / 1_000;
                                let service_us = end_ns.saturating_sub(start_ns) / 1_000;
                                let e2e_us = end_ns.saturating_sub(p.enqueue_ns) / 1_000;
                                t.record_lane(lane, queue_wait_us, service_us, e2e_us);
                                t.span(p.trace, "queue_wait", p.enqueue_ns, start_ns);
                                t.span(p.trace, "service", start_ns, end_ns);
                                t.finish(p.trace, lane, e2e_us, r.partial, r.shed_nodes > 0);
                            }
                        }
                        for (p, mut r) in batch.into_iter().zip(results) {
                            // A rider's k caps only ITS returned list —
                            // the shared scan (and the vote behind the
                            // prediction) already ran at cluster K.
                            if p.k > 0 {
                                r.neighbors.truncate(p.k);
                            }
                            p.slot.fulfill(Ok(r));
                        }
                    } else {
                        // Downstream died (cluster teardown): fail the
                        // whole batch rather than misalign replies.
                        if let Some(t) = shared.tracer.as_ref() {
                            for p in &batch {
                                let e2e_us = end_ns.saturating_sub(p.enqueue_ns) / 1_000;
                                t.finish(p.trace, p.class.idx(), e2e_us, true, true);
                            }
                        }
                        for p in batch {
                            p.slot.fulfill(Err(AdmissionError::Canceled));
                        }
                    }
                }
            })
            .expect("spawn admission dispatcher");

        let shared_c = Arc::clone(&shared);
        let cutter = std::thread::Builder::new()
            .name("admission-cutter".into())
            .spawn(move || {
                let shared = shared_c;
                let max_batch = shared.cfg.max_batch;
                let age_bound_ns = shared.cfg.age_bound.as_nanos().min(u64::MAX as u128) as u64;
                loop {
                    // Phase 1 (locked): wait for a cut to become due.
                    let cut = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            let now = shared.clock.now_ns();
                            if let Some(c) = take_cut(&mut st, max_batch, age_bound_ns, now) {
                                break Some(c);
                            }
                            if st.shutdown {
                                // take_cut drains any residue before this
                                // arm can be reached.
                                debug_assert!(st.is_empty());
                                break None;
                            }
                            match earliest_effective_ns(&st, age_bound_ns) {
                                None => st = shared.cutter_wake.wait(st).unwrap(),
                                Some(dl) => {
                                    // dl > now, else take_cut would have
                                    // deadline-cut above.
                                    let wait = Duration::from_nanos(dl - now);
                                    let (g, _) =
                                        shared.cutter_wake.wait_timeout(st, wait).unwrap();
                                    st = g;
                                }
                            }
                        }
                    };
                    let Some((batch, reason)) = cut else { break };

                    // Phase 2 (unlocked): account the cut, then hand it
                    // to the dispatcher. Counters are recorded *before*
                    // the (possibly blocking) pipeline send so tests and
                    // dashboards observe a cut the moment it is decided.
                    shared.queue.on_dequeue(batch.len());
                    let mut per_class = [0u64; 2];
                    for p in &batch {
                        per_class[p.class.idx()] += 1;
                    }
                    for (idx, n) in per_class.into_iter().enumerate() {
                        if n > 0 {
                            shared.lane_queue[idx].on_dequeue(n as usize);
                            match reason {
                                CutReason::Fill => shared.lane_counters[idx].record_fill(n),
                                CutReason::Deadline => {
                                    shared.lane_counters[idx].record_deadline(n)
                                }
                                CutReason::Aged => shared.lane_counters[idx].record_aged(n),
                                CutReason::Drain => shared.lane_counters[idx].record_drain(n),
                            }
                        }
                    }
                    shared.space_free.notify_all();
                    match reason {
                        CutReason::Fill => shared.cuts.record_fill(),
                        CutReason::Deadline => shared.cuts.record_deadline(),
                        CutReason::Aged => shared.cuts.record_aged(),
                        CutReason::Drain => shared.cuts.record_drain(),
                    }
                    if let Err(std::sync::mpsc::SendError(job)) = cut_tx.send(CutJob { batch }) {
                        // Dispatcher died (a user dispatch closure
                        // panicked): fail this cut AND everything still
                        // queued, and close the queue — otherwise pending
                        // tickets would park forever and later submits
                        // would be admitted into a dead queue.
                        for p in job.batch {
                            p.slot.fulfill(Err(AdmissionError::Canceled));
                        }
                        let mut st = shared.state.lock().unwrap();
                        st.shutdown = true;
                        let stranded: Vec<Pending> =
                            st.monitors.drain(..).chain(st.analytics.drain(..)).collect();
                        drop(st);
                        shared.queue.on_dequeue(stranded.len());
                        shared.space_free.notify_all();
                        for p in stranded {
                            shared.lane_queue[p.class.idx()].on_dequeue(1);
                            p.slot.fulfill(Err(AdmissionError::Canceled));
                        }
                        break;
                    }
                }
                // Cutter exit drops `cut_tx`; the dispatcher drains the
                // remaining pipeline and exits.
            })
            .expect("spawn admission cutter");
        AdmissionQueue { shared, cutter: Some(cutter), dispatcher: Some(dispatcher) }
    }

    /// Admit one [`Class::Monitor`] query with a latency budget, blocking
    /// while the queue is at capacity. The deadline is `now + budget`
    /// (± configured jitter). Monitor is the default class because single
    /// submissions model the paper's latency-first ICU callers; bulk
    /// callers opt into the analytics lane via
    /// [`submit_class`](AdmissionQueue::submit_class).
    pub fn submit(&self, q: &[f32], budget: Duration) -> Result<Ticket, AdmissionError> {
        self.submit_inner(q, budget, Class::Monitor, true)
    }

    /// Admit one query into an explicit scheduling lane.
    pub fn submit_class(
        &self,
        q: &[f32],
        budget: Duration,
        class: Class,
    ) -> Result<Ticket, AdmissionError> {
        self.submit_inner(q, budget, class, true)
    }

    /// Non-blocking admission: `Err(QueueFull)` instead of waiting.
    pub fn try_submit(&self, q: &[f32], budget: Duration) -> Result<Ticket, AdmissionError> {
        self.submit_inner(q, budget, Class::Monitor, false)
    }

    /// Non-blocking admission into an explicit scheduling lane.
    pub fn try_submit_class(
        &self,
        q: &[f32],
        budget: Duration,
        class: Class,
    ) -> Result<Ticket, AdmissionError> {
        self.submit_inner(q, budget, class, false)
    }

    /// Admit one query at an explicit operating point: every [`QuerySpec`]
    /// knob (class, budget, policy, probes/recall hint, comparison cap,
    /// k) rides the request into its cut. Blocking; panics on an invalid
    /// spec (see [`QuerySpec::validate`] — a malformed spec is a caller
    /// bug, same contract as a dimension mismatch).
    pub fn submit_spec(&self, q: &[f32], spec: &QuerySpec) -> Result<Ticket, AdmissionError> {
        self.submit_spec_inner(q, spec, true)
    }

    /// Non-blocking [`submit_spec`](AdmissionQueue::submit_spec):
    /// `Err(QueueFull)` instead of waiting.
    pub fn try_submit_spec(&self, q: &[f32], spec: &QuerySpec) -> Result<Ticket, AdmissionError> {
        self.submit_spec_inner(q, spec, false)
    }

    fn submit_inner(
        &self,
        q: &[f32],
        budget: Duration,
        class: Class,
        block: bool,
    ) -> Result<Ticket, AdmissionError> {
        // The legacy positional doors are exactly a default spec with the
        // class and budget filled in — one admission path, one behavior.
        let spec = QuerySpec { class, budget: Some(budget), ..QuerySpec::default() };
        self.submit_spec_inner(q, &spec, block)
    }

    fn submit_spec_inner(
        &self,
        q: &[f32],
        spec: &QuerySpec,
        block: bool,
    ) -> Result<Ticket, AdmissionError> {
        assert_eq!(q.len(), self.shared.cfg.dim, "query dimension mismatch");
        if let Err(e) = spec.validate() {
            panic!("invalid QuerySpec: {e}");
        }
        let class = spec.class;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if st.shutdown {
                return Err(AdmissionError::ShuttingDown);
            }
            if st.len() < self.shared.cfg.queue_cap {
                break;
            }
            if !block {
                self.shared.queue.on_reject();
                self.shared.lane_queue[class.idx()].on_reject();
                return Err(AdmissionError::QueueFull);
            }
            st = self.shared.space_free.wait(st).unwrap();
        }
        let now = self.shared.clock.now_ns();
        let deadline_ns = match spec.budget {
            Some(budget) => {
                let eff =
                    jittered_budget_ns(budget, self.shared.cfg.budget_jitter, &mut st.jitter_rng);
                now.saturating_add(eff)
            }
            // Budgetless: never deadline-cuts; rides fill/aged/drain cuts
            // (and ships the no-deadline sentinel when alone in a cut).
            // No jitter draw — the RNG stream stays in lockstep with a
            // budget-only workload.
            None => u64::MAX,
        };
        let (writer, reader) = completion_slot();
        // Trace ids are minted at the door (inside the state lock, so
        // ids are dense in admission order) — 0 on an untraced queue.
        let trace = self.shared.tracer.as_ref().map_or(0, |t| t.mint(class.idx()));
        let pending = Pending {
            q: q.to_vec(),
            class,
            enqueue_ns: now,
            deadline_ns,
            probes: spec.requested_probes(),
            max_comparisons: spec.max_comparisons,
            policy: spec.policy,
            k: spec.k,
            trace,
            slot: writer,
        };
        match class {
            Class::Monitor => st.monitors.push_back(pending),
            Class::Analytics => st.analytics.push_back(pending),
        }
        self.shared.queue.on_enqueue(1);
        self.shared.lane_queue[class.idx()].on_enqueue(1);
        drop(st);
        self.shared.cutter_wake.notify_one();
        Ok(Ticket { reader })
    }

    fn lane_stats(&self, class: Class) -> LaneStats {
        let q = &self.shared.lane_queue[class.idx()];
        let c = &self.shared.lane_counters[class.idx()];
        LaneStats {
            depth: q.depth(),
            high_water: q.high_water(),
            submitted: q.enqueued(),
            dispatched_fill: c.fill(),
            dispatched_deadline: c.deadline(),
            dispatched_aged: c.aged(),
            dispatched_drain: c.drain(),
            overruns: c.overruns(),
            partials: c.partials(),
            sheds: c.sheds(),
            inserted: c.inserts(),
            rejected_full: q.rejected(),
            probes: self.shared.lane_probes[class.idx()].load(Ordering::Relaxed),
            ewma_comparisons: self.shared.lane_ewma[class.idx()].load(Ordering::Relaxed),
        }
    }

    /// Attribute `points` ingested (online inserts) to `class` — the
    /// orchestrator calls this on every routed insert batch so the
    /// per-lane `inserted` counter sits next to the partial/shed counts
    /// in [`LaneStats`].
    pub fn note_ingest(&self, class: Class, points: u64) {
        self.shared.lane_counters[class.idx()].record_inserts(points);
    }

    /// Counter snapshot: queue depth + cut-reason mix + per-lane split.
    pub fn stats(&self) -> AdmissionStats {
        AdmissionStats {
            depth: self.shared.queue.depth(),
            high_water: self.shared.queue.high_water(),
            submitted: self.shared.queue.enqueued(),
            completed: self.shared.queue.dequeued(),
            rejected_full: self.shared.queue.rejected(),
            cuts_fill: self.shared.cuts.fill(),
            cuts_deadline: self.shared.cuts.deadline(),
            cuts_aged: self.shared.cuts.aged(),
            cuts_drain: self.shared.cuts.drain(),
            auto_probes: self.shared.cfg.auto_probes.is_some(),
            monitor: self.lane_stats(Class::Monitor),
            analytics: self.lane_stats(Class::Analytics),
        }
    }

    /// Live queue gauges (shared handle; survives the queue, so tests and
    /// dashboards can inspect the final state after shutdown).
    pub fn queue_stats(&self) -> Arc<QueueStats> {
        Arc::clone(&self.shared.queue)
    }

    /// Live cut-reason counters (shared handle, see [`queue_stats`]).
    ///
    /// [`queue_stats`]: AdmissionQueue::queue_stats
    pub fn cut_counters(&self) -> Arc<CutCounters> {
        Arc::clone(&self.shared.cuts)
    }

    /// Live per-lane dispatch/overrun counters (shared handle, see
    /// [`queue_stats`]).
    ///
    /// [`queue_stats`]: AdmissionQueue::queue_stats
    pub fn lane_counters(&self, class: Class) -> Arc<LaneCounters> {
        Arc::clone(&self.shared.lane_counters[class.idx()])
    }
}

impl Drop for AdmissionQueue {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        // Wake everyone: the cutter to drain, blocked submitters to bail.
        self.shared.cutter_wake.notify_all();
        self.shared.space_free.notify_all();
        // Join order matters: the cutter drains the lanes into the
        // pipeline and drops its sender; only then does the dispatcher's
        // receive loop end.
        if let Some(j) = self.cutter.take() {
            let _ = j.join();
        }
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
    }
}

/// Build the dispatcher closure that ships a cut to an Orchestrator root
/// channel and waits for the reduced results (one reply per query, in
/// order). Lives here so [`Orchestrator::enable_admission`] stays a
/// two-liner.
///
/// [`Orchestrator::enable_admission`]: crate::coordinator::Orchestrator::enable_admission
pub(crate) fn root_dispatcher(
    root_tx: Sender<crate::coordinator::orchestrator::RootRequest>,
) -> impl FnMut(
    Vec<f32>,
    usize,
    Budget,
    Class,
    ProbeSpec,
    u64,
) -> Result<Vec<QueryResult>, ClusterError>
       + Send
       + 'static {
    use crate::coordinator::orchestrator::RootRequest;
    move |qs: Vec<f32>,
          nq: usize,
          budget: Budget,
          class: Class,
          probe: ProbeSpec,
          trace: u64|
          -> Result<Vec<QueryResult>, ClusterError> {
        let (tx, rx) = channel();
        root_tx
            .send(RootRequest::Batch { qs, nq, budget, class, probe, trace, reply_to: tx })
            .map_err(|_| ClusterError::Shutdown)?;
        rx.recv().map_err(|_| ClusterError::Shutdown)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    /// Far enough out that MockClock tests never promote it (the default
    /// 25ms age bound is in play unless a test overrides it).
    const NEVER: u64 = u64::MAX / 2;

    fn pending(class: Class, enqueue_ns: u64, deadline_ns: u64) -> Pending {
        let (writer, _reader) = completion_slot();
        Pending {
            q: vec![0.0],
            class,
            enqueue_ns,
            deadline_ns,
            probes: 0,
            max_comparisons: 0,
            policy: None,
            k: 0,
            trace: 0,
            slot: writer,
        }
    }

    /// Build a two-lane state from `(class, enqueue_ns, deadline_ns)`
    /// rows (lane order within each class follows row order).
    fn state(items: &[(Class, u64, u64)], shutdown: bool) -> State {
        let mut st = State {
            monitors: VecDeque::new(),
            analytics: VecDeque::new(),
            shutdown,
            jitter_rng: Xoshiro256::seed_from_u64(0),
        };
        for &(class, enq, dl) in items {
            let p = pending(class, enq, dl);
            match class {
                Class::Monitor => st.monitors.push_back(p),
                Class::Analytics => st.analytics.push_back(p),
            }
        }
        st
    }

    /// All-monitor shorthand for the legacy single-lane cases.
    fn monitors(deadlines: &[u64], shutdown: bool) -> State {
        let rows: Vec<(Class, u64, u64)> =
            deadlines.iter().map(|&d| (Class::Monitor, 0, d)).collect();
        state(&rows, shutdown)
    }

    /// Fake dispatcher that echoes each query's first coordinate back in
    /// `positive_share` — proves result↔caller alignment end to end.
    fn echo(
        flat: Vec<f32>,
        nq: usize,
        _budget: Budget,
        _class: Class,
        _probe: ProbeSpec,
        _trace: u64,
    ) -> Result<Vec<QueryResult>, ClusterError> {
        let dim = if nq == 0 { 0 } else { flat.len() / nq };
        Ok((0..nq)
            .map(|i| QueryResult {
                qid: i as u64,
                neighbors: Vec::new(),
                positive_share: flat[i * dim] as f64,
                prediction: false,
                max_comparisons: 0,
                per_node_comparisons: Vec::new(),
                latency_s: 0.0,
                partial: false,
                shed_nodes: 0,
            })
            .collect())
    }

    // -- table-driven cut decisions (pure, MockClock-style time values) --

    const AGE: u64 = 10_000; // aging bound used by the decision tables

    #[test]
    fn cut_decision_table_single_lane() {
        // All-monitor cases — the PR 2 contract must survive the lane
        // split unchanged. (deadlines, shutdown, max_batch, now) ->
        // expected (len, reason).
        let cases: &[(&[u64], bool, usize, u64, Option<(usize, CutReason)>)] = &[
            // Empty queue never cuts, even under shutdown.
            (&[], false, 4, 0, None),
            (&[], true, 4, 0, None),
            // (a) A full batch cuts immediately, no matter the deadlines.
            (&[1000, 1000, 1000, 1000], false, 4, 0, Some((4, CutReason::Fill))),
            // Overfull queue cuts max_batch, leaving the rest.
            (&[1000; 6], false, 4, 0, Some((4, CutReason::Fill))),
            // Fill wins over an expired deadline (it is the cheaper cut
            // and the expired request rides it anyway).
            (&[0, 1000, 1000, 1000], false, 4, 500, Some((4, CutReason::Fill))),
            // (b) A lone request cuts exactly at its deadline: one tick
            // before -> wait; at the deadline -> cut.
            (&[1000], false, 4, 999, None),
            (&[1000], false, 4, 1000, Some((1, CutReason::Deadline))),
            (&[1000], false, 4, 1001, Some((1, CutReason::Deadline))),
            // The EARLIEST deadline fires the cut, not the FIFO front:
            // a tight budget submitted behind a loose one is honored.
            (&[5000, 1000], false, 4, 1000, Some((2, CutReason::Deadline))),
            (&[5000, 1000], false, 4, 999, None),
            // (d) Shutdown drains a short batch without waiting for the
            // deadline.
            (&[1_000_000], true, 4, 0, Some((1, CutReason::Drain))),
            (&[1_000_000; 3], true, 4, 0, Some((3, CutReason::Drain))),
            // Shutdown with a full queue still counts as a fill cut.
            (&[1_000_000; 4], true, 4, 0, Some((4, CutReason::Fill))),
        ];
        for (i, (deadlines, shutdown, max_batch, now, want)) in cases.iter().enumerate() {
            let mut st = monitors(deadlines, *shutdown);
            let got = take_cut(&mut st, *max_batch, AGE, *now);
            match (got, want) {
                (None, None) => {}
                (Some((batch, reason)), Some((want_len, want_reason))) => {
                    assert_eq!(batch.len(), *want_len, "case {i}: cut size");
                    assert_eq!(reason, *want_reason, "case {i}: cut reason");
                    assert_eq!(st.len(), deadlines.len() - want_len, "case {i}: residue");
                }
                (got, want) => panic!(
                    "case {i}: got {got:?} want {want:?}",
                    got = got.map(|(b, r)| (b.len(), r)),
                    want = want
                ),
            }
        }
    }

    #[test]
    fn cut_decision_table_two_lanes() {
        use Class::{Analytics as A, Monitor as M};
        use CutReason::{Aged, Deadline, Fill};
        // (rows, max_batch, now) -> expected (len, reason). Aging bound
        // is AGE; all states are live (no shutdown).
        let cases: &[(&[(Class, u64, u64)], usize, u64, Option<(usize, CutReason)>)] = &[
            // Both lanes count toward the fill trigger.
            (&[(M, 0, NEVER), (A, 0, NEVER), (M, 0, NEVER), (A, 0, NEVER)], 4, 0, Some((4, Fill))),
            // An analytics *real* deadline triggers a Deadline cut even
            // though it sits behind the monitor lane.
            (&[(M, 0, NEVER), (A, 0, 1000)], 4, 1000, Some((2, Deadline))),
            (&[(M, 0, NEVER), (A, 0, 1000)], 4, 999, None),
            // An analytics request whose age hits the bound fires an
            // Aged cut at enqueue + AGE, long before its real deadline.
            (&[(A, 0, NEVER)], 4, AGE - 1, None),
            (&[(A, 0, NEVER)], 4, AGE, Some((1, Aged))),
            // ... and monitors pending alongside ride the same cut.
            (&[(M, 0, NEVER), (A, 0, NEVER)], 4, AGE, Some((2, Aged))),
            // A monitor deadline tying with a promotion reports Deadline.
            (&[(M, 0, AGE), (A, 0, NEVER)], 4, AGE, Some((2, Deadline))),
            // A monitor deadline earlier than any promotion: Deadline.
            (&[(M, 0, 500), (A, 0, NEVER)], 4, 500, Some((2, Deadline))),
            // Analytics whose real deadline is earlier than its promotion
            // (budget tighter than the aging bound) reports Deadline.
            (&[(A, 0, 500)], 4, 500, Some((1, Deadline))),
        ];
        for (i, (rows, max_batch, now, want)) in cases.iter().enumerate() {
            let mut st = state(rows, false);
            let got = take_cut(&mut st, *max_batch, AGE, *now);
            match (got, want) {
                (None, None) => {}
                (Some((batch, reason)), Some((want_len, want_reason))) => {
                    assert_eq!(batch.len(), *want_len, "case {i}: cut size");
                    assert_eq!(reason, *want_reason, "case {i}: cut reason");
                    assert_eq!(st.len(), rows.len() - want_len, "case {i}: residue");
                }
                (got, want) => panic!(
                    "case {i}: got {got:?} want {want:?}",
                    got = got.map(|(b, r)| (b.len(), r)),
                    want = want
                ),
            }
        }
    }

    #[test]
    fn fill_cut_takes_monitors_before_fresh_analytics() {
        use Class::{Analytics as A, Monitor as M};
        // 2 slots, analytics submitted FIRST but not yet aged: monitors
        // win the batch; the analytics request stays pending.
        let mut st = state(&[(A, 0, NEVER), (M, 0, 5000), (M, 0, 3000)], false);
        let (batch, reason) = take_cut(&mut st, 2, AGE, 0).unwrap();
        assert_eq!(reason, CutReason::Fill);
        assert_eq!(batch.iter().map(|p| p.class).collect::<Vec<_>>(), vec![M, M]);
        // ... and monitors come out deadline-ordered, not arrival-ordered.
        assert_eq!(batch.iter().map(|p| p.deadline_ns).collect::<Vec<_>>(), vec![3000, 5000]);
        assert_eq!(st.analytics.len(), 1);
        assert_eq!(st.monitors.len(), 0);
    }

    #[test]
    fn aged_analytics_preempts_monitors_in_fill_cut() {
        use Class::{Analytics as A, Monitor as M};
        // The anti-starvation bound under sustained fill pressure: once
        // the analytics request is past its age bound it takes a slot
        // ahead of the (far-deadline) monitors.
        let mut st = state(&[(A, 0, NEVER), (M, 0, NEVER), (M, 0, NEVER), (M, 0, NEVER)], false);
        let (batch, reason) = take_cut(&mut st, 2, AGE, AGE).unwrap();
        assert_eq!(reason, CutReason::Fill);
        assert_eq!(batch[0].class, A, "aged analytics must ride the next cut");
        assert_eq!(batch[1].class, M);
        assert_eq!(st.monitors.len(), 2);
        assert_eq!(st.analytics.len(), 0);
    }

    #[test]
    fn aged_analytics_backlog_drains_one_slot_per_fill_cut() {
        use Class::{Analytics as A, Monitor as M};
        // The promotion is capped at one slot per cut: a deep aged
        // backlog must not invert priority and shut monitors out — it
        // drains FIFO, one request per cut, while monitors keep the
        // remaining slots.
        let mut st = state(
            &[(A, 0, NEVER), (A, 0, NEVER), (A, 0, NEVER), (M, 0, 500), (M, 0, 600)],
            false,
        );
        let (batch, reason) = take_cut(&mut st, 2, AGE, AGE).unwrap();
        assert_eq!(reason, CutReason::Fill);
        assert_eq!(batch.iter().map(|p| p.class).collect::<Vec<_>>(), vec![A, M]);
        assert_eq!(batch[1].deadline_ns, 500, "tightest monitor keeps its slot");
        assert_eq!(st.analytics.len(), 2, "backlog drains one per cut");
        assert_eq!(st.monitors.len(), 1);
        // Next cut: the next aged request plus the next monitor.
        let (batch, _) = take_cut(&mut st, 2, AGE, AGE).unwrap();
        assert_eq!(batch.iter().map(|p| p.class).collect::<Vec<_>>(), vec![A, M]);
        assert_eq!(st.analytics.len(), 1);
        assert_eq!(st.monitors.len(), 0);
    }

    #[test]
    fn monitor_residue_keeps_arrival_order() {
        use Class::Monitor as M;
        // Overfull monitor lane: the cut takes the two earliest
        // deadlines; the leftovers go back in arrival order.
        let mut st = state(&[(M, 0, 400), (M, 0, 100), (M, 0, 300), (M, 0, 200)], false);
        let (batch, _) = take_cut(&mut st, 2, AGE, 0).unwrap();
        assert_eq!(batch.iter().map(|p| p.deadline_ns).collect::<Vec<_>>(), vec![100, 200]);
        assert_eq!(
            st.monitors.iter().map(|p| p.deadline_ns).collect::<Vec<_>>(),
            vec![400, 300],
            "residue must preserve arrival order"
        );
    }

    #[test]
    fn deadline_cut_takes_whole_queue_across_lanes() {
        use Class::{Analytics as A, Monitor as M};
        let mut st = state(&[(A, 0, NEVER), (M, 0, 1000), (A, 0, NEVER)], false);
        let (batch, reason) = take_cut(&mut st, 16, AGE, 1000).unwrap();
        assert_eq!(reason, CutReason::Deadline);
        assert_eq!(batch.len(), 3, "a deadline cut takes every pending request");
        assert_eq!(st.len(), 0);
    }

    #[test]
    fn deadline_cut_is_exact_over_mock_time_sweep() {
        // (b) again, as a sweep: walking MockClock time one nanosecond at
        // a time across the deadline flips the decision exactly once.
        let clock = MockClock::new(0);
        let deadline = 4242u64;
        for t in deadline.saturating_sub(3)..deadline + 3 {
            clock.set_ns(t);
            let mut st = monitors(&[deadline], false);
            let cut = take_cut(&mut st, 16, AGE, clock.now_ns());
            assert_eq!(cut.is_some(), t >= deadline, "t={t}");
        }
    }

    #[test]
    fn analytics_promotion_is_exact_over_mock_time_sweep() {
        // The aging bound is as exact as a deadline: one tick before
        // enqueue + age_bound -> wait, at it -> Aged cut.
        let clock = MockClock::new(0);
        let enq = 1234u64;
        for t in (enq + AGE - 3)..(enq + AGE + 3) {
            clock.set_ns(t);
            let mut st = state(&[(Class::Analytics, enq, NEVER)], false);
            let cut = take_cut(&mut st, 16, AGE, clock.now_ns());
            assert_eq!(cut.is_some(), t >= enq + AGE, "t={t}");
            if let Some((_, reason)) = cut {
                assert_eq!(reason, CutReason::Aged);
            }
        }
    }

    #[test]
    fn jittered_deadlines_are_reproducible_from_seed() {
        let budget = Duration::from_millis(10);
        let mut a = Xoshiro256::seed_from_u64(99);
        let mut b = Xoshiro256::seed_from_u64(99);
        let sa: Vec<u64> = (0..32).map(|_| jittered_budget_ns(budget, 0.25, &mut a)).collect();
        let sb: Vec<u64> = (0..32).map(|_| jittered_budget_ns(budget, 0.25, &mut b)).collect();
        assert_eq!(sa, sb, "same seed must give the same deadline stream");
        let base = budget.as_nanos() as u64;
        assert!(sa.iter().any(|&x| x != base), "jitter must actually perturb");
        for &x in &sa {
            let lo = (base as f64 * 0.75) as u64;
            let hi = (base as f64 * 1.25) as u64;
            assert!((lo..=hi).contains(&x), "jitter out of band: {x}");
        }
        // Zero jitter is the identity.
        let mut c = Xoshiro256::seed_from_u64(99);
        assert_eq!(jittered_budget_ns(budget, 0.0, &mut c), base);
    }

    // -- threaded queue behavior (MockClock frozen: no timing assumptions) --

    /// Budgets far enough out that a frozen MockClock can never expire
    /// them — every observable cut in these tests is Fill or Drain.
    const FAR: Duration = Duration::from_secs(3600);

    /// Spin (bounded by real time) until a counter condition holds — the
    /// cutter thread needs a moment to act on a notify; the *outcome* is
    /// deterministic, only its arrival time is scheduler-dependent.
    fn wait_until(mut cond: impl FnMut() -> bool, what: &str) {
        let t0 = Instant::now();
        while !cond() {
            assert!(t0.elapsed() < Duration::from_secs(10), "timed out waiting for {what}");
            std::thread::yield_now();
        }
    }

    #[test]
    fn backpressure_blocks_instead_of_dropping() {
        // (c): cap 2, max_batch 2, pipeline 1 (rendezvous handoff), and a
        // gated dispatcher. With pipelined dispatch the cutter keeps
        // cutting while a batch is gated, so filling the system takes one
        // extra batch: {1,2} gated in the dispatcher, {3,4} parked at the
        // rendezvous, {5,6} pending at capacity. Synchronization is via
        // channel handshakes + counter waits — no sleeps.
        let (evt_tx, evt_rx) = channel::<usize>();
        let (gate_tx, gate_rx) = channel::<()>();
        let dispatch =
            move |flat: Vec<f32>, nq: usize, b: Budget, c: Class, p: ProbeSpec, t: u64| {
                evt_tx.send(nq).unwrap();
                gate_rx.recv().unwrap();
                echo(flat, nq, b, c, p, t)
            };
        let cfg = AdmissionConfig::new(1, 2).with_queue_cap(2).with_pipeline(1);
        let q = AdmissionQueue::start_with_clock(cfg, dispatch, Arc::new(MockClock::new(0)));

        let t1 = q.submit(&[1.0], FAR).unwrap();
        let t2 = q.submit(&[2.0], FAR).unwrap();
        // The cutter fill-cuts {1,2}; the dispatcher picks it up and
        // blocks on the gate.
        assert_eq!(evt_rx.recv().unwrap(), 2);
        let t3 = q.submit(&[3.0], FAR).unwrap();
        let t4 = q.submit(&[4.0], FAR).unwrap();
        // {3,4} is cut (freeing the submission queue) but parks at the
        // rendezvous because the dispatcher is gated.
        wait_until(|| q.stats().completed == 4, "cutter to form the parked batch");
        let t5 = q.submit(&[5.0], FAR).unwrap();
        let t6 = q.submit(&[6.0], FAR).unwrap();
        // Now {5,6} cannot be cut (the cutter is blocked handing {3,4}
        // over) and the queue is at capacity: non-blocking admission must
        // report backpressure, not drop.
        assert!(matches!(q.try_submit(&[7.0], FAR), Err(AdmissionError::QueueFull)));
        assert_eq!(q.stats().rejected_full, 1);

        // A blocking submit parks until a cut frees a slot.
        let q_ref = &q;
        let t7 = std::thread::scope(|s| {
            let blocked = s.spawn(move || q_ref.submit(&[7.0], FAR).unwrap());
            gate_tx.send(()).unwrap(); // release {1,2}
            assert_eq!(evt_rx.recv().unwrap(), 2); // dispatcher took {3,4}
            gate_tx.send(()).unwrap(); // release {3,4}
            assert_eq!(evt_rx.recv().unwrap(), 2); // dispatcher took {5,6}
            gate_tx.send(()).unwrap(); // release {5,6}
            let t7 = blocked.join().unwrap();
            gate_tx.send(()).unwrap(); // pre-arm the gate for the drain cut
            t7
        });
        drop(q); // drains {7}

        // Every admitted request resolved, in alignment with its payload.
        for (t, want) in
            [(t1, 1.0), (t2, 2.0), (t3, 3.0), (t4, 4.0), (t5, 5.0), (t6, 6.0), (t7, 7.0)]
        {
            assert_eq!(t.wait().unwrap().positive_share, want);
        }
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        // (d): frozen clock + far deadlines + short queue means nothing
        // can cut before shutdown; dropping the queue must still resolve
        // every ticket via drain cuts.
        let cfg = AdmissionConfig::new(1, 100).with_queue_cap(100);
        let q = AdmissionQueue::start_with_clock(cfg, echo, Arc::new(MockClock::new(0)));
        let queue_stats = q.queue_stats();
        let cut_counters = q.cut_counters();
        let tickets: Vec<Ticket> =
            (0..5).map(|i| q.submit(&[i as f32], FAR).unwrap()).collect();
        drop(q);
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().positive_share, i as f64, "drain order");
        }
        assert_eq!(queue_stats.enqueued(), 5);
        assert_eq!(queue_stats.dequeued(), 5);
        assert_eq!(queue_stats.depth(), 0);
        assert!(cut_counters.drain() >= 1, "drain cut must be recorded");
        assert_eq!(cut_counters.deadline(), 0, "frozen clock cannot deadline-cut");
    }

    #[test]
    fn cluster_failure_surfaces_through_tickets() {
        // A dispatch that fails (dead cluster) must fulfill every rider
        // of the batch with a typed error — no panic, no hang, and the
        // queue keeps serving later batches.
        let dispatch =
            move |flat: Vec<f32>, nq: usize, b: Budget, c: Class, p: ProbeSpec, t: u64| {
                if flat[0] < 0.0 {
                    Err(ClusterError::Shutdown)
                } else {
                    echo(flat, nq, b, c, p, t)
                }
            };
        let cfg = AdmissionConfig::new(1, 2);
        let q = AdmissionQueue::start_with_clock(cfg, dispatch, Arc::new(MockClock::new(0)));
        let bad1 = q.submit(&[-1.0], FAR).unwrap();
        let bad2 = q.submit(&[-2.0], FAR).unwrap();
        assert_eq!(bad1.wait().unwrap_err(), AdmissionError::Cluster(ClusterError::Shutdown));
        assert_eq!(bad2.wait().unwrap_err(), AdmissionError::Cluster(ClusterError::Shutdown));
        let good1 = q.submit(&[3.0], FAR).unwrap();
        let good2 = q.submit(&[4.0], FAR).unwrap();
        assert_eq!(good1.wait().unwrap().positive_share, 3.0);
        assert_eq!(good2.wait().unwrap().positive_share, 4.0);
    }

    #[test]
    fn spec_riders_resolve_cut_knobs() {
        // Two spec riders share one fill cut: the cut ships the WIDEST
        // probe count, the TIGHTEST nonzero comparison cap, and the
        // STRICTEST policy named by any rider.
        let (cap_tx, cap_rx) = channel::<(Budget, ProbeSpec)>();
        let dispatch =
            move |flat: Vec<f32>, nq: usize, b: Budget, c: Class, p: ProbeSpec, t: u64| {
                cap_tx.send((b, p)).unwrap();
                echo(flat, nq, b, c, p, t)
            };
        let q = AdmissionQueue::start_with_clock(
            AdmissionConfig::new(1, 2),
            dispatch,
            Arc::new(MockClock::new(0)),
        );
        let spec_a = QuerySpec::default()
            .with_budget(FAR)
            .with_probes(4)
            .with_max_comparisons(100)
            .with_policy(BudgetPolicy::Shed);
        let spec_b =
            QuerySpec::default().with_budget(FAR).with_probes(2).with_max_comparisons(50);
        let ta = q.submit_spec(&[1.0], &spec_a).unwrap();
        let tb = q.submit_spec(&[2.0], &spec_b).unwrap();
        assert_eq!(ta.wait().unwrap().positive_share, 1.0);
        assert_eq!(tb.wait().unwrap().positive_share, 2.0);
        let (budget, probe) = cap_rx.recv().unwrap();
        assert_eq!(probe.probes, 4, "widest rider sets the shared scan");
        assert_eq!(probe.max_comparisons, 50, "tightest nonzero cap wins");
        assert_eq!(budget.policy, BudgetPolicy::Shed, "strictest rider policy escalates");
        assert!(!budget.is_none());
    }

    #[test]
    fn budgetless_spec_ships_the_no_deadline_sentinel() {
        let (cap_tx, cap_rx) = channel::<(Budget, ProbeSpec)>();
        let dispatch =
            move |flat: Vec<f32>, nq: usize, b: Budget, c: Class, p: ProbeSpec, t: u64| {
                cap_tx.send((b, p)).unwrap();
                echo(flat, nq, b, c, p, t)
            };
        let q = AdmissionQueue::start_with_clock(
            AdmissionConfig::new(1, 1),
            dispatch,
            Arc::new(MockClock::new(0)),
        );
        // Default spec: no budget, auto probes with the controller off —
        // the dispatched cut is budgetless at baseline knobs.
        let t = q.submit_spec(&[3.0], &QuerySpec::default()).unwrap();
        assert_eq!(t.wait().unwrap().positive_share, 3.0);
        let (budget, probe) = cap_rx.recv().unwrap();
        assert!(budget.is_none(), "no rider budget -> no-deadline sentinel");
        assert!(probe.is_baseline(), "controller off -> baseline probes, no cap");
    }

    #[test]
    fn auto_probes_controller_steps_on_feedback() {
        // Feedback plant: comparisons = |x|, partial iff x < 0. Target
        // 1000: cheap clean cuts step the lane up; a partial steps down.
        let dispatch =
            move |flat: Vec<f32>, nq: usize, _b: Budget, _c: Class, _p: ProbeSpec, _t: u64| {
            Ok((0..nq)
                .map(|i| QueryResult {
                    qid: i as u64,
                    neighbors: Vec::new(),
                    positive_share: 0.0,
                    prediction: false,
                    max_comparisons: flat[i].abs() as u64,
                    per_node_comparisons: Vec::new(),
                    latency_s: 0.0,
                    partial: flat[i] < 0.0,
                    shed_nodes: 0,
                })
                .collect())
        };
        let cfg = AdmissionConfig::new(1, 1)
            .with_auto_probes(AutoProbes { min: 1, max: 4, target_comparisons: 1000 });
        let q = AdmissionQueue::start_with_clock(cfg, dispatch, Arc::new(MockClock::new(0)));
        assert!(q.stats().auto_probes);
        assert_eq!(q.stats().monitor.probes, 1, "controller starts at min");
        q.submit(&[16.0], FAR).unwrap().wait().unwrap();
        let st = q.stats().monitor;
        assert_eq!(st.probes, 2, "clean under-target cut steps up");
        assert_eq!(st.ewma_comparisons, 16, "first observation seeds the EWMA");
        q.submit(&[16.0], FAR).unwrap().wait().unwrap();
        assert_eq!(q.stats().monitor.probes, 3);
        q.submit(&[-8.0], FAR).unwrap().wait().unwrap();
        let st = q.stats().monitor;
        assert_eq!(st.probes, 2, "a partial answer steps the lane back down");
        assert_eq!(st.ewma_comparisons, ewma_fold(16, 8)); // round((7·16 + 8)/8) = 15
        // Monitor traffic leaves the analytics lane untouched.
        assert_eq!(q.stats().analytics.probes, 1);
        assert_eq!(q.stats().analytics.ewma_comparisons, 0);
    }

    #[test]
    fn ewma_fold_saturates_and_rounds() {
        // Wrap safety: with the old u64 arithmetic, 7 * prev overflowed
        // for prev > u64::MAX / 7 and the EWMA wrapped to garbage. The
        // u128 fold must saturate instead.
        assert_eq!(ewma_fold(u64::MAX, u64::MAX), u64::MAX);
        assert_eq!(ewma_fold(u64::MAX, 0), ((7u128 * u128::from(u64::MAX) + 4) / 8) as u64);
        let big = 1u64 << 63;
        assert_eq!(ewma_fold(big, big), big, "fixed point at any magnitude");
        assert!(ewma_fold(big, 0) < big, "huge EWMAs still decay");
        // Round-to-nearest, not truncation: from 16 with a constant
        // observation of 23, truncation computes (7·16 + 23)/8 = 16
        // forever — a stale floor. Rounding must walk up to within 3.
        let mut e = 16u64;
        for _ in 0..16 {
            e = ewma_fold(e, 23);
        }
        assert_eq!(e, 20, "rounded EWMA converges to within 3 of obs=23");
        assert_eq!((7u64 * 16 + 23) / 8, 16, "truncation would have been stuck at 16");
    }

    #[test]
    fn huge_observation_cannot_wrap_the_lane_ewma() {
        // Controller-level version of the wrap test: a plant reporting
        // absurd comparison counts (f32::MAX casts saturate to u64::MAX)
        // must leave the lane EWMA huge-but-sane — above target, never
        // wrapped to a small number that would step probes UP.
        let dispatch =
            move |flat: Vec<f32>, nq: usize, _b: Budget, _c: Class, _p: ProbeSpec, _t: u64| {
            Ok((0..nq)
                .map(|i| QueryResult {
                    qid: i as u64,
                    neighbors: Vec::new(),
                    positive_share: 0.0,
                    prediction: false,
                    max_comparisons: flat[i].abs() as u64,
                    per_node_comparisons: Vec::new(),
                    latency_s: 0.0,
                    partial: false,
                    shed_nodes: 0,
                })
                .collect())
        };
        let cfg = AdmissionConfig::new(1, 1)
            .with_auto_probes(AutoProbes { min: 1, max: 8, target_comparisons: 1000 });
        let q = AdmissionQueue::start_with_clock(cfg, dispatch, Arc::new(MockClock::new(0)));
        q.submit(&[f32::MAX], FAR).unwrap().wait().unwrap();
        let seed = q.stats().monitor.ewma_comparisons;
        assert_eq!(seed, u64::MAX, "first observation seeds the saturated count");
        for _ in 0..4 {
            q.submit(&[f32::MAX], FAR).unwrap().wait().unwrap();
            let st = q.stats().monitor;
            assert_eq!(st.ewma_comparisons, u64::MAX, "flood holds the fixed point, no wrap");
            assert_eq!(st.probes, 1, "over-target flood keeps the lane pinned at min");
        }
    }

    #[test]
    fn submit_after_shutdown_is_rejected() {
        let cfg = AdmissionConfig::new(1, 4);
        let q = AdmissionQueue::start_with_clock(cfg, echo, Arc::new(MockClock::new(0)));
        // Force the shutdown flag the way Drop does, then observe submit.
        q.shared.state.lock().unwrap().shutdown = true;
        q.shared.cutter_wake.notify_all();
        assert_eq!(q.submit(&[0.0], FAR).unwrap_err(), AdmissionError::ShuttingDown);
        assert_eq!(q.try_submit(&[0.0], FAR).unwrap_err(), AdmissionError::ShuttingDown);
    }

    #[test]
    fn zero_budget_requests_all_complete_with_deadline_cuts() {
        // Real clock, budget 0: every request's deadline is already due,
        // so each cut is a deadline cut (max_batch too large to fill).
        // Assertions are about values and counters, never about timing.
        let cfg = AdmissionConfig::new(2, 64);
        let q = AdmissionQueue::start(cfg, echo);
        let tickets: Vec<Ticket> = (0..8)
            .map(|i| q.submit(&[i as f32, 0.5], Duration::ZERO).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.wait().unwrap().positive_share, i as f64);
        }
        let st = q.stats();
        assert_eq!(st.submitted, 8);
        assert_eq!(st.completed, 8);
        assert_eq!(st.cuts_fill, 0, "64-wide batches cannot fill with 8 requests");
        assert!(st.cuts_deadline >= 1);
    }

    // -- completion slot --

    #[test]
    fn completion_slot_basic_paths() {
        // Fulfill before wait.
        let (w, r) = completion_slot();
        w.fulfill(7u32);
        assert_eq!(r.wait(), Some(7));
        // Drop before wait.
        let (w, r) = completion_slot::<u32>();
        drop(w);
        assert_eq!(r.wait(), None);
        // Drop the reader first: fulfilling must not panic or leak waiters.
        let (w, r) = completion_slot();
        drop(r);
        w.fulfill(9u32);
    }

    #[test]
    fn completion_slot_handoff_stress() {
        // 100 iterations of a racing producer/consumer pair (loom-style
        // schedule exploration with plain threads): whichever side wins
        // the race, the value must arrive exactly once.
        for round in 0..100u64 {
            let (w, r) = completion_slot();
            let producer = std::thread::spawn(move || w.fulfill(round * 7 + 1));
            let consumer = std::thread::spawn(move || r.wait());
            producer.join().unwrap();
            assert_eq!(consumer.join().unwrap(), Some(round * 7 + 1), "round {round}");
        }
        // Same race against a writer that drops instead of fulfilling.
        for round in 0..100u64 {
            let (w, r) = completion_slot::<u64>();
            let consumer = std::thread::spawn(move || r.wait());
            let producer = std::thread::spawn(move || drop(w));
            producer.join().unwrap();
            assert_eq!(consumer.join().unwrap(), None, "round {round}");
        }
    }
}
