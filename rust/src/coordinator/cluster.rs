//! Cluster assembly — the Root's construction duties (paper §3): assign
//! each node its O(n/ν) shard of the dataset and broadcast the outer hash
//! specification so every node uses the same hash-family instances.
//!
//! With [`ClusterConfig::with_replication`] each shard is served by a
//! [`ReplicaSet`] of N interchangeable nodes built from the same shard
//! slice, id base and hash spec — so replicas hold bit-identical tables
//! and any one of them can answer for the shard. The per-replica
//! [`Health`] machine, hedge/timeout policy and reconnect backoff are
//! configured here ([`FailoverConfig`]) and enforced by the shard
//! dispatchers in [`crate::coordinator::orchestrator`].

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::orchestrator::{NodeHandle, Orchestrator};
use crate::data::Dataset;
use crate::engine::native::NativeEngine;
use crate::engine::DistanceEngine;
use crate::knn::predict::VoteConfig;
use crate::node::node::LocalNode;
use crate::runtime::XlaService;
use crate::slsh::{SealPolicy, SlshParams, LIVE_ID_STRIDE};
use crate::util::clock::SystemClock;
use crate::util::threadpool::chunk_ranges;

/// Which distance engine the cores use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Portable Rust scan.
    Native,
    /// AOT JAX/Pallas kernels through PJRT (requires `make artifacts`).
    Xla,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }
}

/// Per-replica health as the shard dispatcher sees it.
///
/// * `Up` — answering normally; preferred for dispatch.
/// * `Suspect` — alive but slow (a request of its outlived the hedge
///   delay or the request timeout) or freshly reconnected; deprioritized
///   but still routable. Any successful reply promotes back to `Up`.
/// * `Down` — a request or heartbeat failed outright (broken transport,
///   node error); excluded from routing until a
///   [`reconnect`](NodeHandle::reconnect) succeeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Up,
    Suspect,
    Down,
}

/// Failure-handling policy for the shard dispatchers: hedge and timeout
/// deadlines, heartbeat cadence, reconnect backoff. All decisions read
/// the orchestrator's injected [`Clock`](crate::util::clock::Clock), so
/// every one of these is pinnable under a `MockClock` in tests.
///
/// The defaults are deliberately conservative so an unreplicated cluster
/// behaves exactly as before: a 250 ms hedge delay never fires on
/// in-process microsecond queries, and with one replica per shard there
/// is nobody to hedge to anyway.
#[derive(Debug, Clone)]
pub struct FailoverConfig {
    /// Hedge a query to the next replica when the preferred one has not
    /// answered within this delay.
    pub hedge_after: Duration,
    /// Give up on a request entirely after this long and synthesize a
    /// shed reply (queries) or report the acks gathered so far (inserts).
    pub request_timeout: Duration,
    /// Liveness/seal-poll heartbeat cadence per replica.
    pub heartbeat_every: Duration,
    /// First reconnect attempt fires this long after a replica goes
    /// `Down`; attempt `n` waits `base · 2ⁿ` (capped, jittered).
    pub reconnect_base: Duration,
    /// Ceiling on the exponential reconnect delay (before jitter).
    pub reconnect_cap: Duration,
    /// Jitter fraction in `[0, 1]`: attempt `n`'s delay is stretched by
    /// up to this fraction, deterministically from `seed` and `n` — so
    /// replicas that died together don't re-dial in lockstep, yet tests
    /// can assert the exact schedule.
    pub reconnect_jitter: f64,
    /// Seed for the deterministic jitter.
    pub seed: u64,
}

impl Default for FailoverConfig {
    fn default() -> FailoverConfig {
        FailoverConfig {
            hedge_after: Duration::from_millis(250),
            request_timeout: Duration::from_secs(10),
            heartbeat_every: Duration::from_millis(500),
            reconnect_base: Duration::from_millis(50),
            reconnect_cap: Duration::from_secs(5),
            reconnect_jitter: 0.2,
            seed: 0x5EED,
        }
    }
}

/// SplitMix64 — tiny, seedable, good enough for jitter.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

impl FailoverConfig {
    /// Delay before reconnect attempt `attempt` (0-based): capped
    /// exponential backoff `min(base · 2ᵃ, cap)` stretched by a
    /// deterministic jitter in `[0, reconnect_jitter]` derived from
    /// `(seed, attempt)`. Pure — the fault-tolerance tests assert the
    /// schedule exactly.
    pub fn reconnect_delay(&self, attempt: u32) -> Duration {
        let base = self.reconnect_base.as_nanos();
        let exp = base.saturating_mul(1u128 << attempt.min(63));
        let capped = exp.min(self.reconnect_cap.as_nanos());
        let h = splitmix64(self.seed ^ u64::from(attempt).wrapping_mul(0x9E3779B97F4A7C15));
        let frac = (h >> 11) as f64 / (1u64 << 53) as f64;
        let jitter = (capped as f64 * self.reconnect_jitter.clamp(0.0, 1.0) * frac) as u128;
        let total = capped.saturating_add(jitter).min(u64::MAX as u128) as u64;
        Duration::from_nanos(total)
    }
}

/// N interchangeable nodes serving the same shard: same slice, same id
/// base, same hash spec — bit-identical tables, so the dispatcher can
/// route a query to ANY of them (and hedge/fail over among them) without
/// changing the answer. Inserts fan out to all live replicas to keep
/// them identical.
pub struct ReplicaSet {
    /// The shard these replicas serve; also the reducer's ordering key.
    pub shard_id: usize,
    pub replicas: Vec<Box<dyn NodeHandle>>,
}

impl ReplicaSet {
    pub fn new(shard_id: usize, replicas: Vec<Box<dyn NodeHandle>>) -> ReplicaSet {
        assert!(!replicas.is_empty(), "replica set for shard {shard_id} is empty");
        ReplicaSet { shard_id, replicas }
    }
}

/// Cluster topology + engine choice.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of SLSH nodes (ν).
    pub nu: usize,
    /// Cores per node (p).
    pub p: usize,
    pub engine: EngineKind,
    pub vote: VoteConfig,
    /// Replicas per shard (≥ 1). One means no replication — the exact
    /// pre-replication topology.
    pub replication: usize,
    /// Hedge/timeout/heartbeat/backoff policy for the shard dispatchers.
    pub failover: FailoverConfig,
}

impl ClusterConfig {
    pub fn new(nu: usize, p: usize) -> Self {
        Self {
            nu,
            p,
            engine: EngineKind::Native,
            vote: VoteConfig::default(),
            replication: 1,
            failover: FailoverConfig::default(),
        }
    }

    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Serve every shard with `r` interchangeable replicas.
    pub fn with_replication(mut self, r: usize) -> Self {
        assert!(r >= 1, "replication factor must be at least 1");
        self.replication = r;
        self
    }

    pub fn with_failover(mut self, failover: FailoverConfig) -> Self {
        self.failover = failover;
        self
    }
}

/// A running DSLSH cluster: the Orchestrator plus the resources backing
/// it (the XLA service thread when the XLA engine is selected).
pub struct Cluster {
    pub orchestrator: Orchestrator,
    /// Keeps the PJRT service alive as long as the nodes using it.
    _xla: Option<Arc<XlaService>>,
}

impl std::ops::Deref for Cluster {
    type Target = Orchestrator;
    fn deref(&self) -> &Orchestrator {
        &self.orchestrator
    }
}

/// Start the XLA service when selected and yield the per-node engine
/// factory — the one spot both cluster builders share, so the engine
/// wiring cannot diverge between the batch-built and live paths.
fn engine_setup(
    kind: EngineKind,
) -> Result<(Option<Arc<XlaService>>, impl Fn(usize) -> Vec<Box<dyn DistanceEngine>>)> {
    let xla = match kind {
        EngineKind::Xla => Some(Arc::new(XlaService::start()?)),
        EngineKind::Native => None,
    };
    let xla_f = xla.clone();
    let make = move |p: usize| -> Vec<Box<dyn DistanceEngine>> {
        (0..p)
            .map(|_| match &xla_f {
                Some(svc) => Box::new(svc.engine()) as Box<dyn DistanceEngine>,
                None => Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>,
            })
            .collect()
    };
    Ok((xla, make))
}

/// Build and start a cluster over `data`.
///
/// Shards are contiguous equal ranges (the Root "assigns each node its
/// share of the dataset"); global point ids are shard-offset so the
/// Reducer's K-NN refers to positions in `data`.
pub fn build_cluster(data: &Dataset, params: &SlshParams, cfg: &ClusterConfig) -> Result<Cluster> {
    assert!(cfg.nu > 0 && cfg.p > 0 && cfg.replication > 0);
    let (xla, make_engines) = engine_setup(cfg.engine)?;
    let mut sets: Vec<ReplicaSet> = Vec::with_capacity(cfg.nu);
    for (node_id, range) in chunk_ranges(data.len(), cfg.nu).into_iter().enumerate() {
        let id_base = range.start as u64;
        let shard = Arc::new(data.shard(range));
        // Replicas share the shard slice (Arc) and the id base, and are
        // built from the same deterministic params — bit-identical
        // tables, so any replica answers for the shard.
        let replicas: Vec<Box<dyn NodeHandle>> = (0..cfg.replication)
            .map(|_| {
                Box::new(LocalNode::spawn(
                    node_id,
                    Arc::clone(&shard),
                    id_base,
                    params,
                    cfg.p,
                    make_engines(cfg.p),
                )) as Box<dyn NodeHandle>
            })
            .collect();
        sets.push(ReplicaSet::new(node_id, replicas));
    }
    let orchestrator =
        Orchestrator::start_replicated(sets, params.k, cfg.vote.clone(), cfg.failover.clone());
    Ok(Cluster { orchestrator, _xla: xla })
}

/// Build and start an EMPTY live (streaming) cluster: ν live nodes ready
/// for [`Orchestrator::insert_batch`] routing, each sealing its delta by
/// `policy` (size-or-age on the system clock). Node `i` mints global ids
/// from `i * LIVE_ID_STRIDE`, so ids stay disjoint without per-insert
/// coordination; queries broadcast and reduce exactly like a batch-built
/// cluster's.
pub fn build_live_cluster(
    params: &SlshParams,
    cfg: &ClusterConfig,
    policy: SealPolicy,
) -> Result<Cluster> {
    assert!(cfg.nu > 0 && cfg.p > 0 && cfg.replication > 0);
    let (xla, make_engines) = engine_setup(cfg.engine)?;
    let mut sets: Vec<ReplicaSet> = Vec::with_capacity(cfg.nu);
    for node_id in 0..cfg.nu {
        // Replicas of a live shard each own a store, but mint ids from
        // the same base and apply the same batches in the same order
        // (the dispatcher fans every insert to all live replicas), so
        // they stay bit-identical.
        let replicas: Vec<Box<dyn NodeHandle>> = (0..cfg.replication)
            .map(|_| {
                Box::new(LocalNode::spawn_live(
                    node_id,
                    node_id as u64 * LIVE_ID_STRIDE,
                    params,
                    cfg.p,
                    make_engines(cfg.p),
                    Arc::new(SystemClock::new()),
                    policy,
                )) as Box<dyn NodeHandle>
            })
            .collect();
        sets.push(ReplicaSet::new(node_id, replicas));
    }
    let orchestrator =
        Orchestrator::start_replicated(sets, params.k, cfg.vote.clone(), cfg.failover.clone());
    Ok(Cluster { orchestrator, _xla: xla })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_corpus, CorpusConfig, WindowSpec};
    use crate::lsh::family::LayerSpec;

    fn corpus() -> crate::data::Corpus {
        build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 3000, 30, 21))
    }

    fn params(data: &Dataset) -> SlshParams {
        let (lo, hi) = data.value_range();
        SlshParams::lsh_only(LayerSpec::outer_l1(data.dim, 40, 12, lo, hi, 5), 10)
    }

    #[test]
    fn cluster_answers_queries() {
        let c = corpus();
        let cluster = build_cluster(&c.data, &params(&c.data), &ClusterConfig::new(2, 2)).unwrap();
        assert_eq!(cluster.num_nodes(), 2);
        assert_eq!(cluster.total_processors(), 4);
        let r = cluster.query(c.queries.point(0)).unwrap();
        assert!(r.neighbors.len() <= 10);
        assert_eq!(r.per_node_comparisons.len(), 2);
        assert_eq!(r.per_node_comparisons[0].len(), 2);
        assert!(r.latency_s > 0.0);
    }

    #[test]
    fn global_ids_are_consistent_across_shards() {
        let c = corpus();
        let cluster = build_cluster(&c.data, &params(&c.data), &ClusterConfig::new(3, 1)).unwrap();
        // Query with dataset point 2500 (lives in the last shard): its own
        // global id must come back at distance 0.
        let r = cluster.query(c.data.point(2500)).unwrap();
        assert_eq!(r.neighbors[0].id, 2500);
        assert_eq!(r.neighbors[0].dist, 0.0);
        // Neighbor labels must match the dataset at the global id.
        for n in &r.neighbors {
            assert_eq!(n.label, c.data.labels[n.id as usize], "id {}", n.id);
        }
    }

    #[test]
    fn prediction_invariant_to_topology_lsh_mode() {
        // LSH-only mode: identical outer spec on every node ⇒ the global
        // candidate union (hence K-NN and prediction) is independent of
        // (ν, p).
        let c = corpus();
        let p = params(&c.data);
        let mut reference: Option<Vec<(bool, u64)>> = None;
        for (nu, pc) in [(1usize, 1usize), (1, 4), (2, 2), (4, 1), (5, 3)] {
            let cluster = build_cluster(&c.data, &p, &ClusterConfig::new(nu, pc)).unwrap();
            let answers: Vec<(bool, u64)> = (0..15)
                .map(|i| {
                    let r = cluster.query(c.queries.point(i)).unwrap();
                    (r.prediction, r.neighbors.first().map(|n| n.id).unwrap_or(u64::MAX))
                })
                .collect();
            match &reference {
                None => reference = Some(answers),
                Some(rf) => assert_eq!(&answers, rf, "topology ({nu},{pc}) changed output"),
            }
        }
    }

    #[test]
    fn live_cluster_ingests_routes_round_robin_and_answers() {
        let c = corpus();
        let p = params(&c.data);
        let cluster =
            build_live_cluster(&p, &ClusterConfig::new(2, 2), SealPolicy::by_size(500)).unwrap();
        let d = &c.data;
        let batch = 250usize;
        for b in 0..8 {
            let at = b * batch;
            let out = cluster
                .insert_batch(
                    &d.points[at * d.dim..(at + batch) * d.dim],
                    &d.labels[at..at + batch],
                )
                .unwrap();
            assert_eq!(out.node, b % 2, "round-robin routing");
            assert_eq!(out.accepted, batch as u64);
            assert_eq!(out.node_total, ((b / 2) as u64 + 1) * batch as u64);
        }
        let stats = cluster.ingest_stats();
        assert_eq!(stats.batches, 8);
        assert_eq!(stats.points, 2000);
        assert_eq!(stats.sealed_segments, 4, "1000 points per node / 500 per seal");
        // A point inserted in batch `b` lives on node `b % 2` at local
        // index `(b / 2) * batch + off` — its global id must come back at
        // distance 0 through the ordinary broadcast/reduce query path.
        for probe in [0usize, 260, 990, 1999] {
            let (b, off) = (probe / batch, probe % batch);
            let want = (b % 2) as u64 * LIVE_ID_STRIDE + ((b / 2) * batch + off) as u64;
            let r = cluster.query(d.point(probe)).unwrap();
            assert!(
                r.neighbors.iter().any(|n| n.id == want && n.dist == 0.0),
                "probe {probe}: want id {want} in {:?}",
                r.neighbors
            );
        }
    }

    #[test]
    fn max_comparisons_decreases_with_more_processors() {
        let c = corpus();
        let p = params(&c.data);
        let mut meds = Vec::new();
        for (nu, pc) in [(1usize, 2usize), (2, 2), (4, 2)] {
            let cluster = build_cluster(&c.data, &p, &ClusterConfig::new(nu, pc)).unwrap();
            let mut comps: Vec<f64> = (0..20)
                .map(|i| cluster.query(c.queries.point(i)).unwrap().max_comparisons as f64)
                .collect();
            comps.sort_by(|a, b| a.partial_cmp(b).unwrap());
            meds.push(comps[comps.len() / 2]);
        }
        assert!(
            meds[2] < meds[0],
            "scaling failed: medians {meds:?} should decrease with pν"
        );
    }

    #[test]
    fn reconnect_backoff_schedule_is_exact_without_jitter() {
        let cfg = FailoverConfig {
            reconnect_base: Duration::from_millis(10),
            reconnect_cap: Duration::from_millis(160),
            reconnect_jitter: 0.0,
            ..FailoverConfig::default()
        };
        // 10, 20, 40, 80, 160, then pinned at the 160 ms cap.
        let want = [10u64, 20, 40, 80, 160, 160, 160];
        for (attempt, w) in want.iter().enumerate() {
            assert_eq!(
                cfg.reconnect_delay(attempt as u32),
                Duration::from_millis(*w),
                "attempt {attempt}"
            );
        }
        // Huge attempt numbers must not overflow past the cap.
        assert_eq!(cfg.reconnect_delay(u32::MAX), Duration::from_millis(160));
    }

    #[test]
    fn reconnect_jitter_is_deterministic_and_bounded() {
        let cfg = FailoverConfig {
            reconnect_base: Duration::from_millis(100),
            reconnect_cap: Duration::from_secs(10),
            reconnect_jitter: 0.5,
            seed: 42,
            ..FailoverConfig::default()
        };
        for attempt in 0..8u32 {
            let d = cfg.reconnect_delay(attempt);
            let floor = Duration::from_millis(100 * (1 << attempt));
            let ceil = floor + floor.mul_f64(0.5);
            assert!(d >= floor && d <= ceil, "attempt {attempt}: {d:?} outside [{floor:?}, {ceil:?}]");
            // Same (seed, attempt) → same delay, different seed → (almost
            // surely) different delay.
            assert_eq!(d, cfg.reconnect_delay(attempt));
        }
        let other = FailoverConfig { seed: 43, ..cfg };
        assert_ne!(other.reconnect_delay(0), cfg.reconnect_delay(0));
    }

    #[test]
    fn replicated_cluster_matches_unreplicated_bit_for_bit() {
        // All replicas healthy: replication must be invisible — same
        // neighbors, same comparison counts, no partials, no sheds.
        let c = corpus();
        let p = params(&c.data);
        let plain = build_cluster(&c.data, &p, &ClusterConfig::new(2, 2)).unwrap();
        let replicated =
            build_cluster(&c.data, &p, &ClusterConfig::new(2, 2).with_replication(2)).unwrap();
        assert_eq!(replicated.num_nodes(), 2, "replication must not change shard count");
        for i in 0..10 {
            let a = plain.query(c.queries.point(i)).unwrap();
            let b = replicated.query(c.queries.point(i)).unwrap();
            assert_eq!(a.neighbors, b.neighbors, "query {i}");
            assert_eq!(a.max_comparisons, b.max_comparisons, "query {i}");
            assert_eq!(a.per_node_comparisons, b.per_node_comparisons, "query {i}");
            assert_eq!(a.partial, b.partial, "query {i}");
            assert_eq!(a.shed_nodes, b.shed_nodes, "query {i}");
        }
        assert_eq!(replicated.failover_stats().synthesized_sheds, 0);
        assert_eq!(replicated.failover_stats().failovers, 0);
    }
}
