//! Cluster assembly — the Root's construction duties (paper §3): assign
//! each node its O(n/ν) shard of the dataset and broadcast the outer hash
//! specification so every node uses the same hash-family instances.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::orchestrator::{NodeHandle, Orchestrator};
use crate::data::Dataset;
use crate::engine::native::NativeEngine;
use crate::engine::DistanceEngine;
use crate::knn::predict::VoteConfig;
use crate::node::node::LocalNode;
use crate::runtime::XlaService;
use crate::slsh::{SealPolicy, SlshParams, LIVE_ID_STRIDE};
use crate::util::clock::SystemClock;
use crate::util::threadpool::chunk_ranges;

/// Which distance engine the cores use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Portable Rust scan.
    Native,
    /// AOT JAX/Pallas kernels through PJRT (requires `make artifacts`).
    Xla,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }
}

/// Cluster topology + engine choice.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of SLSH nodes (ν).
    pub nu: usize,
    /// Cores per node (p).
    pub p: usize,
    pub engine: EngineKind,
    pub vote: VoteConfig,
}

impl ClusterConfig {
    pub fn new(nu: usize, p: usize) -> Self {
        Self { nu, p, engine: EngineKind::Native, vote: VoteConfig::default() }
    }

    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }
}

/// A running DSLSH cluster: the Orchestrator plus the resources backing
/// it (the XLA service thread when the XLA engine is selected).
pub struct Cluster {
    pub orchestrator: Orchestrator,
    /// Keeps the PJRT service alive as long as the nodes using it.
    _xla: Option<Arc<XlaService>>,
}

impl std::ops::Deref for Cluster {
    type Target = Orchestrator;
    fn deref(&self) -> &Orchestrator {
        &self.orchestrator
    }
}

/// Start the XLA service when selected and yield the per-node engine
/// factory — the one spot both cluster builders share, so the engine
/// wiring cannot diverge between the batch-built and live paths.
fn engine_setup(
    kind: EngineKind,
) -> Result<(Option<Arc<XlaService>>, impl Fn(usize) -> Vec<Box<dyn DistanceEngine>>)> {
    let xla = match kind {
        EngineKind::Xla => Some(Arc::new(XlaService::start()?)),
        EngineKind::Native => None,
    };
    let xla_f = xla.clone();
    let make = move |p: usize| -> Vec<Box<dyn DistanceEngine>> {
        (0..p)
            .map(|_| match &xla_f {
                Some(svc) => Box::new(svc.engine()) as Box<dyn DistanceEngine>,
                None => Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>,
            })
            .collect()
    };
    Ok((xla, make))
}

/// Build and start a cluster over `data`.
///
/// Shards are contiguous equal ranges (the Root "assigns each node its
/// share of the dataset"); global point ids are shard-offset so the
/// Reducer's K-NN refers to positions in `data`.
pub fn build_cluster(data: &Dataset, params: &SlshParams, cfg: &ClusterConfig) -> Result<Cluster> {
    assert!(cfg.nu > 0 && cfg.p > 0);
    let (xla, make_engines) = engine_setup(cfg.engine)?;
    let mut nodes: Vec<Box<dyn NodeHandle>> = Vec::with_capacity(cfg.nu);
    for (node_id, range) in chunk_ranges(data.len(), cfg.nu).into_iter().enumerate() {
        let id_base = range.start as u64;
        let shard = Arc::new(data.shard(range));
        let node =
            LocalNode::spawn(node_id, shard, id_base, params, cfg.p, make_engines(cfg.p));
        nodes.push(Box::new(node));
    }
    let orchestrator = Orchestrator::start(nodes, params.k, cfg.vote.clone());
    Ok(Cluster { orchestrator, _xla: xla })
}

/// Build and start an EMPTY live (streaming) cluster: ν live nodes ready
/// for [`Orchestrator::insert_batch`] routing, each sealing its delta by
/// `policy` (size-or-age on the system clock). Node `i` mints global ids
/// from `i * LIVE_ID_STRIDE`, so ids stay disjoint without per-insert
/// coordination; queries broadcast and reduce exactly like a batch-built
/// cluster's.
pub fn build_live_cluster(
    params: &SlshParams,
    cfg: &ClusterConfig,
    policy: SealPolicy,
) -> Result<Cluster> {
    assert!(cfg.nu > 0 && cfg.p > 0);
    let (xla, make_engines) = engine_setup(cfg.engine)?;
    let mut nodes: Vec<Box<dyn NodeHandle>> = Vec::with_capacity(cfg.nu);
    for node_id in 0..cfg.nu {
        let node = LocalNode::spawn_live(
            node_id,
            node_id as u64 * LIVE_ID_STRIDE,
            params,
            cfg.p,
            make_engines(cfg.p),
            Arc::new(SystemClock::new()),
            policy,
        );
        nodes.push(Box::new(node));
    }
    let orchestrator = Orchestrator::start(nodes, params.k, cfg.vote.clone());
    Ok(Cluster { orchestrator, _xla: xla })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_corpus, CorpusConfig, WindowSpec};
    use crate::lsh::family::LayerSpec;

    fn corpus() -> crate::data::Corpus {
        build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 3000, 30, 21))
    }

    fn params(data: &Dataset) -> SlshParams {
        let (lo, hi) = data.value_range();
        SlshParams::lsh_only(LayerSpec::outer_l1(data.dim, 40, 12, lo, hi, 5), 10)
    }

    #[test]
    fn cluster_answers_queries() {
        let c = corpus();
        let cluster = build_cluster(&c.data, &params(&c.data), &ClusterConfig::new(2, 2)).unwrap();
        assert_eq!(cluster.num_nodes(), 2);
        assert_eq!(cluster.total_processors(), 4);
        let r = cluster.query(c.queries.point(0));
        assert!(r.neighbors.len() <= 10);
        assert_eq!(r.per_node_comparisons.len(), 2);
        assert_eq!(r.per_node_comparisons[0].len(), 2);
        assert!(r.latency_s > 0.0);
    }

    #[test]
    fn global_ids_are_consistent_across_shards() {
        let c = corpus();
        let cluster = build_cluster(&c.data, &params(&c.data), &ClusterConfig::new(3, 1)).unwrap();
        // Query with dataset point 2500 (lives in the last shard): its own
        // global id must come back at distance 0.
        let r = cluster.query(c.data.point(2500));
        assert_eq!(r.neighbors[0].id, 2500);
        assert_eq!(r.neighbors[0].dist, 0.0);
        // Neighbor labels must match the dataset at the global id.
        for n in &r.neighbors {
            assert_eq!(n.label, c.data.labels[n.id as usize], "id {}", n.id);
        }
    }

    #[test]
    fn prediction_invariant_to_topology_lsh_mode() {
        // LSH-only mode: identical outer spec on every node ⇒ the global
        // candidate union (hence K-NN and prediction) is independent of
        // (ν, p).
        let c = corpus();
        let p = params(&c.data);
        let mut reference: Option<Vec<(bool, u64)>> = None;
        for (nu, pc) in [(1usize, 1usize), (1, 4), (2, 2), (4, 1), (5, 3)] {
            let cluster = build_cluster(&c.data, &p, &ClusterConfig::new(nu, pc)).unwrap();
            let answers: Vec<(bool, u64)> = (0..15)
                .map(|i| {
                    let r = cluster.query(c.queries.point(i));
                    (r.prediction, r.neighbors.first().map(|n| n.id).unwrap_or(u64::MAX))
                })
                .collect();
            match &reference {
                None => reference = Some(answers),
                Some(rf) => assert_eq!(&answers, rf, "topology ({nu},{pc}) changed output"),
            }
        }
    }

    #[test]
    fn live_cluster_ingests_routes_round_robin_and_answers() {
        let c = corpus();
        let p = params(&c.data);
        let cluster =
            build_live_cluster(&p, &ClusterConfig::new(2, 2), SealPolicy::by_size(500)).unwrap();
        let d = &c.data;
        let batch = 250usize;
        for b in 0..8 {
            let at = b * batch;
            let out = cluster.insert_batch(
                &d.points[at * d.dim..(at + batch) * d.dim],
                &d.labels[at..at + batch],
            );
            assert_eq!(out.node, b % 2, "round-robin routing");
            assert_eq!(out.accepted, batch as u64);
            assert_eq!(out.node_total, ((b / 2) as u64 + 1) * batch as u64);
        }
        let stats = cluster.ingest_stats();
        assert_eq!(stats.batches, 8);
        assert_eq!(stats.points, 2000);
        assert_eq!(stats.sealed_segments, 4, "1000 points per node / 500 per seal");
        // A point inserted in batch `b` lives on node `b % 2` at local
        // index `(b / 2) * batch + off` — its global id must come back at
        // distance 0 through the ordinary broadcast/reduce query path.
        for probe in [0usize, 260, 990, 1999] {
            let (b, off) = (probe / batch, probe % batch);
            let want = (b % 2) as u64 * LIVE_ID_STRIDE + ((b / 2) * batch + off) as u64;
            let r = cluster.query(d.point(probe));
            assert!(
                r.neighbors.iter().any(|n| n.id == want && n.dist == 0.0),
                "probe {probe}: want id {want} in {:?}",
                r.neighbors
            );
        }
    }

    #[test]
    fn max_comparisons_decreases_with_more_processors() {
        let c = corpus();
        let p = params(&c.data);
        let mut meds = Vec::new();
        for (nu, pc) in [(1usize, 2usize), (2, 2), (4, 2)] {
            let cluster = build_cluster(&c.data, &p, &ClusterConfig::new(nu, pc)).unwrap();
            let mut comps: Vec<f64> = (0..20)
                .map(|i| cluster.query(c.queries.point(i)).max_comparisons as f64)
                .collect();
            comps.sort_by(|a, b| a.partial_cmp(b).unwrap());
            meds.push(comps[comps.len() / 2]);
        }
        assert!(
            meds[2] < meds[0],
            "scaling failed: medians {meds:?} should decrease with pν"
        );
    }
}
