//! The Orchestrator (paper Figure 1, §3): Root, Forwarder and Reducer
//! processes coordinating ν SLSH shards, each served by a replica group.
//!
//! * **Root** — the public API; coordinates query resolution (and, at
//!   construction time, shard assignment + hash-spec broadcast, done in
//!   [`crate::coordinator::cluster`]).
//! * **Forwarder** — broadcasts each query to every shard dispatcher.
//! * **Reducer** — gathers the ν shard-local K-NN sets and keeps the K
//!   closest (reduction), then the Root turns them into the prediction.
//!
//! # Failure semantics
//!
//! Every shard is served by a [`ReplicaSet`] of interchangeable nodes
//! behind a *shard dispatcher* thread — the failure-containment seam:
//!
//! * **Health.** Each replica carries a [`Health`] state (`Up` →
//!   `Suspect` → `Down`) driven by request outcomes and a periodic
//!   [`heartbeat`](NodeHandle::heartbeat) on the injectable
//!   [`Clock`]. Transport errors mark a replica `Down` (excluded from
//!   routing); a request that outlives the hedge delay demotes it to
//!   `Suspect` (deprioritized); any successful reply promotes back to
//!   `Up`.
//! * **Hedged reads.** A query is dispatched to the best-ranked replica;
//!   if no reply arrives within [`FailoverConfig::hedge_after`] it is
//!   *hedged* to the next replica. First reply wins; the loser's late
//!   reply is drained and ignored (it still refreshes health).
//! * **Graceful degradation.** When a replica fails mid-request the
//!   dispatcher fails over to the next one; when *no* replica can answer
//!   (all `Down`, or [`FailoverConfig::request_timeout`] elapses) the
//!   dispatcher synthesizes a shed [`NodeReply`] — exactly the shape a
//!   node-side budget shed produces — so the Reducer still completes the
//!   query and the caller sees [`QueryResult::shed_nodes`]` > 0` instead
//!   of a hang or a panic. A query NEVER errors because a shard is
//!   unavailable; it degrades to a partial answer.
//! * **Recovery.** `Down` replicas are re-dialed through
//!   [`NodeHandle::reconnect`] on a capped exponential backoff with
//!   deterministic jitter ([`FailoverConfig::reconnect_delay`]).
//! * **Ingest.** Inserts fan out to every live replica of the target
//!   shard (replicas stay bit-identical because they apply the same
//!   batches in the same order from the same id base); the ack reports
//!   how many replicas made the batch durable. Zero acks IS an error —
//!   [`ClusterError::ShardUnavailable`] — because dropping ICU data
//!   silently is worse than failing loudly.
//!
//! The dispatcher guarantees exactly one reply per (shard, query) —
//! possibly synthesized — so the Reducer's `received == ν` completion
//! rule holds even with dead nodes, and the Root's qid-monotone
//! sequencing is preserved.
//!
//! All coordination processes are real threads connected by channels,
//! mirroring the cloud deployment; nodes are [`NodeHandle`]s so the same
//! Orchestrator drives in-process thread-group nodes and remote TCP
//! nodes (which reconnect with the same backoff schedule).
//!
//! Queries enter through three doors — [`Orchestrator::query_spec`] (one
//! query, the paper's ICU latency model),
//! [`Orchestrator::query_batch_spec_flat`] (a caller-formed block), and —
//! once [`Orchestrator::enable_admission`] has installed the
//! deadline-aware admission layer — [`Orchestrator::submit_spec`], which
//! coalesces *independent* callers into shared cuts under per-request
//! latency budgets (see [`crate::coordinator::admission`]). All three
//! take the same typed [`QuerySpec`] (class, latency budget, enforcement
//! policy, multi-probe width, comparison cap, K), whose default
//! reproduces the legacy positional entry points bit-for-bit; those old
//! signatures survive as thin deprecated shims.
//!
//! [`ReplicaSet`]: crate::coordinator::cluster::ReplicaSet
//! [`Health`]: crate::coordinator::cluster::Health
//! [`FailoverConfig::hedge_after`]: crate::coordinator::cluster::FailoverConfig
//! [`FailoverConfig::request_timeout`]: crate::coordinator::cluster::FailoverConfig
//! [`FailoverConfig::reconnect_delay`]: crate::coordinator::cluster::FailoverConfig::reconnect_delay

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::admission::{
    root_dispatcher, AdmissionConfig, AdmissionError, AdmissionQueue, Budget, BudgetPolicy, Class,
    Ticket,
};
use crate::coordinator::cluster::{FailoverConfig, Health, ReplicaSet};
use crate::knn::heap::{Neighbor, TopK};
use crate::lsh::probe::{ProbeSpec, MAX_PROBES};
use crate::knn::predict::{positive_share, VoteConfig};
use crate::node::node::{HeartbeatReply, InsertReply, NodeInfo, NodeReply};
use crate::runtime::service::{FailoverCounters, FailoverStats, IngestCounters, IngestStats};
use crate::runtime::trace::{NodeSpan, Tracer};
use crate::util::clock::{Clock, SystemClock};

/// Sentinel budget for batches that carry no latency deadline (direct
/// [`Orchestrator::query_batch`] calls, as opposed to admission cuts).
pub const NO_BUDGET: u64 = u64::MAX;

/// The per-request accuracy/latency operating point — ONE typed knob
/// bundle that every query door accepts ([`Orchestrator::query_spec`],
/// [`Orchestrator::query_batch_spec_flat`], [`Orchestrator::submit_spec`],
/// the wire's `QueryBatchBudget` frame and the HTTP edge's
/// `POST /v1/query` body all carry the same fields).
///
/// `QuerySpec::default()` reproduces today's behavior exactly: no
/// deadline, one bucket probed per outer table, no comparison cap, the
/// cluster's configured K — bit-identical to the positional entry points
/// it replaces. Every field widens or tightens one axis:
///
/// * `class` — scheduling lane on the admission path (monitor lane has
///   strict priority; analytics rides leftovers, aging-protected).
/// * `budget` — latency budget; `None` means no deadline. On the direct
///   path the deadline is enforced node-side from dispatch; on the
///   admission path it also drives the cutter.
/// * `policy` — node-side enforcement contract for the budget; `None`
///   inherits ([`BudgetPolicy::PartialResults`] on the direct path when a
///   budget is set; the queue's configured policy on the admission path).
///   On a shared admission cut the strictest policy requested by any
///   rider governs the whole cut.
/// * `probes` — buckets probed per outer hash table (multi-probe LSH):
///   probe 1 is the query's own bucket; probes 2..P visit near-neighbor
///   buckets in margin order (see [`crate::lsh::probe`]). More probes buy
///   recall at the price of comparisons — equal recall from fewer tables.
///   `0` = auto: resolve via `recall_hint` if set, else the lane's
///   feedback-controlled default (admission path with
///   [`AutoProbes`](crate::coordinator::admission::AutoProbes) enabled)
///   or 1.
/// * `recall_hint` — declarative alternative to `probes` (mutually
///   exclusive with it): target recall in `(0, 1]`, mapped to a probe
///   count (≤0.5→1, ≤0.75→2, ≤0.9→4, else 8).
/// * `max_comparisons` — hard per-worker candidate budget; the scan
///   truncates its candidate walk once this many comparisons have been
///   spent and flags the answer `partial`. `0` = unlimited. Deterministic
///   (clock-free), unlike the latency budget.
/// * `k` — caps the *returned* neighbor list; `0` = the cluster's
///   configured K. The vote/prediction still uses the full cluster K-NN,
///   so prediction semantics do not depend on the caller's display size.
#[derive(Debug, Clone, PartialEq)]
pub struct QuerySpec {
    pub class: Class,
    pub budget: Option<Duration>,
    pub policy: Option<BudgetPolicy>,
    pub probes: u32,
    pub recall_hint: Option<f32>,
    pub max_comparisons: u64,
    pub k: usize,
}

impl Default for QuerySpec {
    fn default() -> QuerySpec {
        QuerySpec {
            class: Class::Monitor,
            budget: None,
            policy: None,
            probes: 0,
            recall_hint: None,
            max_comparisons: 0,
            k: 0,
        }
    }
}

impl QuerySpec {
    /// The default operating point (see the type docs).
    pub fn new() -> QuerySpec {
        QuerySpec::default()
    }

    pub fn with_class(mut self, class: Class) -> QuerySpec {
        self.class = class;
        self
    }

    pub fn with_budget(mut self, budget: Duration) -> QuerySpec {
        self.budget = Some(budget);
        self
    }

    pub fn with_policy(mut self, policy: BudgetPolicy) -> QuerySpec {
        self.policy = Some(policy);
        self
    }

    pub fn with_probes(mut self, probes: u32) -> QuerySpec {
        self.probes = probes;
        self
    }

    pub fn with_recall_hint(mut self, hint: f32) -> QuerySpec {
        self.recall_hint = Some(hint);
        self
    }

    pub fn with_max_comparisons(mut self, cap: u64) -> QuerySpec {
        self.max_comparisons = cap;
        self
    }

    pub fn with_k(mut self, k: usize) -> QuerySpec {
        self.k = k;
        self
    }

    /// Field-level validation, shared by the typed API (which asserts on
    /// it) and the HTTP edge (which turns the message into a 400).
    pub fn validate(&self) -> Result<(), String> {
        if self.probes > 0 && self.recall_hint.is_some() {
            return Err("probes and recall_hint are mutually exclusive".into());
        }
        if self.probes > MAX_PROBES {
            return Err(format!("probes {} exceeds maximum {MAX_PROBES}", self.probes));
        }
        if let Some(h) = self.recall_hint {
            if !(h > 0.0 && h <= 1.0) {
                return Err(format!("recall_hint {h} outside (0, 1]"));
            }
        }
        Ok(())
    }

    /// Probe count this spec *requests*: explicit `probes`, else the
    /// `recall_hint` mapping, else `0` (= auto — the admission layer
    /// resolves it to the lane default, the direct path to 1).
    pub fn requested_probes(&self) -> u32 {
        if self.probes > 0 {
            return self.probes.min(MAX_PROBES);
        }
        match self.recall_hint {
            Some(h) if h <= 0.5 => 1,
            Some(h) if h <= 0.75 => 2,
            Some(h) if h <= 0.9 => 4,
            Some(_) => 8,
            None => 0,
        }
    }

    /// The node-level probe knobs for the DIRECT path (auto resolves
    /// to 1 — no controller in the loop).
    pub fn probe_spec(&self) -> ProbeSpec {
        ProbeSpec::new(self.requested_probes().max(1), self.max_comparisons)
    }

    /// The node-level [`Budget`] for the direct path: no budget → the
    /// no-deadline sentinel; a budget with no explicit policy enforces
    /// [`BudgetPolicy::PartialResults`].
    pub(crate) fn direct_budget(&self) -> Budget {
        match self.budget {
            None => Budget::none(),
            Some(d) => Budget::enforced(
                d.as_micros().min((NO_BUDGET - 1) as u128) as u64,
                self.policy.unwrap_or(BudgetPolicy::PartialResults),
            ),
        }
    }
}

/// A transport- or node-level failure talking to ONE replica: the
/// connection broke, the frame was malformed, the node rejected the
/// request. Node errors never escape the shard dispatcher as-is — they
/// drive health transitions (the replica goes `Down`) and either
/// failover or degradation to a synthesized shed reply.
#[derive(Debug, Clone)]
pub struct NodeError {
    /// Node that failed.
    pub node_id: usize,
    /// Human-readable failure description (best effort; for logs).
    pub detail: String,
}

impl NodeError {
    pub fn new(node_id: usize, detail: impl Into<String>) -> NodeError {
        NodeError { node_id, detail: detail.into() }
    }
}

impl std::fmt::Display for NodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "node {}: {}", self.node_id, self.detail)
    }
}

impl std::error::Error for NodeError {}

/// A cluster-level failure the caller must handle. Queries only ever
/// return [`ClusterError::Shutdown`] (a dead shard degrades to
/// [`QueryResult::shed_nodes`], never an error); inserts additionally
/// return [`ClusterError::ShardUnavailable`] when zero replicas of the
/// target shard acknowledged the batch — the data is NOT durable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterError {
    /// The orchestrator's coordination threads are gone (the cluster was
    /// dropped, or a coordination thread died). Retrying cannot succeed.
    Shutdown,
    /// No replica of shard `shard` accepted the request.
    ShardUnavailable { shard: usize },
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Shutdown => write!(f, "cluster is shut down"),
            ClusterError::ShardUnavailable { shard } => {
                write!(f, "no live replica of shard {shard}")
            }
        }
    }
}

impl std::error::Error for ClusterError {}

/// Abstraction over a node the shard dispatcher can reach (in-process
/// thread group or TCP-remote process). Every request is fallible: a
/// `NodeError` means THIS replica failed, and the dispatcher routes
/// around it — implementations must return errors, not panic, on broken
/// transports.
pub trait NodeHandle: Send {
    fn node_id(&self) -> usize;
    fn info(&self) -> NodeInfo;
    fn query(&mut self, q: &[f32]) -> Result<NodeReply, NodeError>;

    /// Resolve a block of `nq` queries (`qs` row-major `nq × dim` — one
    /// shared flat buffer end to end, so batching adds no per-query or
    /// per-node allocations). The default falls back to per-query round
    /// trips; in-process and TCP nodes override it to ship the whole
    /// block at once and ride the cores' batched resolution path
    /// (batched hashing + reused scratch arena).
    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Result<Vec<NodeReply>, NodeError> {
        if nq == 0 {
            return Ok(Vec::new());
        }
        debug_assert_eq!(qs.len() % nq, 0);
        let dim = qs.len() / nq;
        qs.chunks_exact(dim).map(|q| self.query(q)).collect()
    }

    /// Batch resolution carrying the admission cut's [`Budget`] — the
    /// remaining latency budget (µs until the batch's most urgent
    /// deadline, computed once at dispatch; [`NO_BUDGET`] when the batch
    /// has none) plus the enforcement policy — and the cut's scheduling
    /// class ([`Class::Monitor`] if any monitor rides it). The default
    /// ignores both — the orchestrator-side cutter already made the cut —
    /// but real nodes enforce the budget (early-exit/shed per policy) and
    /// transports (TCP) ship budget, policy and class with the frame so
    /// the far side enforces the same deadline and attributes overruns to
    /// the right lane.
    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        _budget: Budget,
        _class: Class,
    ) -> Result<Vec<NodeReply>, NodeError> {
        self.query_batch(qs, nq)
    }

    /// [`query_batch_budget`](NodeHandle::query_batch_budget) plus the
    /// request's multi-probe knobs ([`ProbeSpec`]). The default ignores
    /// the knobs and serves the baseline — correct for handles that
    /// cannot carry them (a baseline spec IS the legacy behavior; a
    /// wider spec degrades to it rather than failing). `LocalNode` and
    /// `RemoteNode` override to thread the knobs to every worker / over
    /// the wire.
    fn query_batch_spec(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        _probe: ProbeSpec,
    ) -> Result<Vec<NodeReply>, NodeError> {
        self.query_batch_budget(qs, nq, budget, class)
    }

    /// [`query_batch_spec`](NodeHandle::query_batch_spec) plus the
    /// request's trace id (`0` = untraced). The default ignores the id —
    /// correct for handles that cannot carry it (the replies' own
    /// `scan_ns`/`tables` spans are still real). `RemoteNode` overrides
    /// to ship the id with the frame and verify the reply echoes it.
    fn query_batch_traced(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
        _trace: u64,
    ) -> Result<Vec<NodeReply>, NodeError> {
        self.query_batch_spec(qs, nq, budget, class, probe)
    }

    /// Append a batch of labeled points to this node's live index
    /// (`points` row-major `labels.len() × dim`), returning once every
    /// core has indexed them. Only live nodes
    /// ([`LocalNode::spawn_live`](crate::node::node::LocalNode::spawn_live),
    /// [`RemoteNode::connect_live`](crate::net::tcp::RemoteNode::connect_live))
    /// support inserts; the default errors so a misrouted insert fails
    /// loudly instead of silently dropping ICU data.
    fn insert_batch(
        &mut self,
        _points: &[f32],
        _labels: &[bool],
    ) -> Result<InsertReply, NodeError> {
        Err(NodeError::new(
            self.node_id(),
            "node does not accept online inserts (live nodes only)",
        ))
    }

    /// Liveness + ingest-progress probe, fired periodically by the shard
    /// dispatcher ([`FailoverConfig::heartbeat_every`]). An `Err` marks
    /// the replica `Down`. For live nodes the reply doubles as the
    /// cluster-level seal poll: answering a heartbeat runs the node's
    /// age-seal check ([`LocalNode::poll_seal`]), so a COMPLETELY quiet
    /// remote stream still seals by age and the seal count flows back
    /// into [`Orchestrator::ingest_stats`]. The default answers "alive,
    /// not live-indexed" — correct for any batch-built node.
    ///
    /// [`FailoverConfig::heartbeat_every`]: crate::coordinator::cluster::FailoverConfig
    /// [`LocalNode::poll_seal`]: crate::node::node::LocalNode::poll_seal
    fn heartbeat(&mut self) -> Result<HeartbeatReply, NodeError> {
        Ok(HeartbeatReply::not_live())
    }

    /// Re-establish a broken transport (TCP re-dial + build replay).
    /// Called by the shard dispatcher on the capped-exponential-backoff
    /// schedule after the replica goes `Down`; `Ok` promotes it back to
    /// `Suspect` (the next successful reply makes it `Up`). The default
    /// errors: an in-process node that died cannot be revived.
    fn reconnect(&mut self) -> Result<(), NodeError> {
        Err(NodeError::new(self.node_id(), "reconnect not supported"))
    }
}

impl NodeHandle for crate::node::node::LocalNode {
    fn node_id(&self) -> usize {
        crate::node::node::LocalNode::node_id(self)
    }
    fn info(&self) -> NodeInfo {
        crate::node::node::LocalNode::info(self).clone()
    }
    fn query(&mut self, q: &[f32]) -> Result<NodeReply, NodeError> {
        Ok(crate::node::node::LocalNode::query(self, q))
    }
    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Result<Vec<NodeReply>, NodeError> {
        Ok(crate::node::node::LocalNode::query_batch(self, qs, nq))
    }
    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Result<Vec<NodeReply>, NodeError> {
        Ok(crate::node::node::LocalNode::query_batch_budget(self, qs, nq, budget, class))
    }
    fn query_batch_spec(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
    ) -> Result<Vec<NodeReply>, NodeError> {
        Ok(crate::node::node::LocalNode::query_batch_spec(self, qs, nq, budget, class, probe))
    }
    fn insert_batch(&mut self, points: &[f32], labels: &[bool]) -> Result<InsertReply, NodeError> {
        if !self.is_live() {
            return Err(NodeError::new(
                crate::node::node::LocalNode::node_id(self),
                "node does not accept online inserts (live nodes only)",
            ));
        }
        Ok(crate::node::node::LocalNode::insert_batch(self, points, labels))
    }
    fn heartbeat(&mut self) -> Result<HeartbeatReply, NodeError> {
        if self.is_live() {
            let r = self.poll_seal();
            Ok(HeartbeatReply {
                live: true,
                total: r.total,
                sealed_now: r.sealed_now,
                sealed_total: r.sealed_total,
            })
        } else {
            Ok(HeartbeatReply::not_live())
        }
    }
}

/// Final, reduced answer for one query.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub qid: u64,
    /// Global K-NN across all nodes.
    pub neighbors: Vec<Neighbor>,
    /// Weighted-vote positive share and thresholded prediction.
    pub positive_share: f64,
    pub prediction: bool,
    /// Max comparisons across ALL processors (the paper's speed metric).
    pub max_comparisons: u64,
    /// Per-node, per-core comparison counts, in ascending node-id order
    /// (deterministic regardless of reply arrival order).
    pub per_node_comparisons: Vec<Vec<u64>>,
    /// Wall-clock latency of the full round trip (seconds).
    pub latency_s: f64,
    /// True when at least one node answered from an incomplete scan under
    /// budget enforcement (includes sheds): `neighbors` covers a prefix
    /// of the cluster's tables, not all of them — recall was traded for
    /// the deadline. Always `false` under `BudgetPolicy::LogOnly` and for
    /// un-budgeted queries.
    pub partial: bool,
    /// Shards that contributed NO scan work to this answer: a node-side
    /// budget shed (budget already spent on arrival under
    /// `BudgetPolicy::Shed`), or a shard whose replicas were all dead or
    /// too slow — the dispatcher synthesized the shed so the answer could
    /// complete in time instead of hanging.
    pub shed_nodes: u32,
}

/// Cluster-level outcome of one routed insert batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertOutcome {
    /// Shard the batch was routed to (round-robin).
    pub node: usize,
    /// Points appended.
    pub accepted: u64,
    /// That shard's total points afterwards.
    pub node_total: u64,
    /// Segments the batch caused to seal.
    pub sealed_now: u64,
    /// That shard's total sealed segments afterwards.
    pub sealed_total: u64,
    /// Replicas of the target shard that acknowledged the batch (≥ 1; a
    /// zero-ack insert returns [`ClusterError::ShardUnavailable`]
    /// instead). Below the replication factor means a replica was down
    /// and will be missing these points until it is rebuilt.
    pub replicas_acked: u32,
}

/// One shard's ack for a replicated insert (internal).
struct ShardInsert {
    reply: InsertReply,
    replicas_acked: u32,
}

#[derive(Clone)]
enum Job {
    /// Flat row-major `nq × dim` block; query `i` has id `qid0 + i`.
    /// `budget` is the admission cut's remaining latency budget plus
    /// enforcement policy ([`Budget::none`] for caller-formed blocks);
    /// `class` is the cut's scheduling class (monitor if any monitor
    /// rides it); `probe` the cut's multi-probe knobs
    /// ([`ProbeSpec::BASELINE`] for default-spec requests — the
    /// bit-identical legacy path). Single queries travel as
    /// batches of one.
    Batch {
        qid0: u64,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
        /// Trace id of the request (or the cut's lead rider); `0` =
        /// untraced. Travels with the job to every shard so node spans
        /// and the `QueryBatchBudget` frame carry it.
        trace: u64,
    },
    /// Online insert, ROUTED to shard `target` (never broadcast — each
    /// point lives on exactly one shard); the dispatcher acks straight
    /// to the caller through `reply`, bypassing the query Reducer.
    Insert {
        target: usize,
        points: Arc<Vec<f32>>,
        labels: Arc<Vec<bool>>,
        reply: Sender<Result<ShardInsert, ClusterError>>,
    },
}

pub(crate) enum RootRequest {
    /// Flat row-major `nq × dim` block (single queries are batches of
    /// one — there is exactly one serving core).
    Batch {
        qs: Vec<f32>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
        /// Trace id (`0` = untraced), forwarded into [`Job::Batch`].
        trace: u64,
        reply_to: Sender<Vec<QueryResult>>,
    },
}

/// One unit of work for a replica runner thread. `seq` tags the outcome
/// so the dispatcher can tell a current reply from a stale one (a hedge
/// loser, a timed-out straggler) — stale outcomes still update health
/// but never complete a request twice.
enum ReplicaJob {
    Run { seq: u64, job: Job },
    Insert { seq: u64, points: Arc<Vec<f32>>, labels: Arc<Vec<bool>> },
    Heartbeat { seq: u64 },
    /// Re-dial, then replay the shard's acked insert history so a live
    /// replica rejoins with the SAME points (and ids) its peers hold —
    /// a reconnected replica that skipped the replay would serve an
    /// empty shard while ranked healthy.
    Reconnect { seq: u64, backlog: Vec<(Arc<Vec<f32>>, Arc<Vec<bool>>)> },
}

enum ReplicaOutcome {
    /// `(qid, reply)` per query of the job, in qid order.
    Queries(Result<Vec<(u64, NodeReply)>, NodeError>),
    Insert(Result<InsertReply, NodeError>),
    Heartbeat(Result<HeartbeatReply, NodeError>),
    /// `Ok(n)` = reconnected and replayed `n` backlog batches; the
    /// dispatcher promotes the replica only if `n` still matches its
    /// log (batches may land while the replay is in flight).
    Reconnect(Result<u64, NodeError>),
}

/// Orchestrator over ν replicated shards.
pub struct Orchestrator {
    root_tx: Sender<RootRequest>,
    /// Direct line to the Forwarder for routed (non-broadcast) work:
    /// online inserts skip the Root's query sequencing entirely, so a
    /// sustained ingest stream never serializes behind queries.
    ingest_tx: Sender<Job>,
    /// Deadline-aware admission layer (see [`Orchestrator::enable_admission`]).
    admission: Option<AdmissionQueue>,
    threads: Vec<JoinHandle<()>>,
    node_infos: Vec<NodeInfo>,
    k: usize,
    nu: usize,
    /// Round-robin insert-routing cursor.
    next_ingest: AtomicUsize,
    /// Cluster-wide ingest telemetry (batches, points, seals).
    ingest: Arc<IngestCounters>,
    /// Hedge / failover / reconnect telemetry, shared with the shard
    /// dispatchers.
    failover: Arc<FailoverCounters>,
    /// End-to-end tracing + latency histograms, shared with the shard
    /// dispatchers and (once installed) the admission queue and edge.
    tracer: Arc<Tracer>,
}

/// Cap on a dispatcher's blocking wait while a request is in flight: the
/// dispatcher re-reads the [`Clock`] at least this often (real time), so
/// hedge/timeout decisions track a `MockClock` that tests advance
/// without any real-time coupling.
const RESOLVE_POLL: Duration = Duration::from_millis(1);
/// Cap on the idle wait between jobs (heartbeat / reconnect duty cycle).
const IDLE_POLL: Duration = Duration::from_millis(5);

fn dur_ns(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

impl Orchestrator {
    /// Wire Root → Forwarder → shard dispatchers → Reducer → Root and
    /// start all threads, one single-replica shard per node (the
    /// unreplicated topology; identical behavior to replication factor 1
    /// under [`FailoverConfig::default`]).
    pub fn start(nodes: Vec<Box<dyn NodeHandle>>, k: usize, vote: VoteConfig) -> Orchestrator {
        let sets = nodes
            .into_iter()
            .enumerate()
            .map(|(shard, n)| ReplicaSet::new(shard, vec![n]))
            .collect();
        Self::start_replicated(sets, k, vote, FailoverConfig::default())
    }

    /// Start over explicit replica groups (see
    /// [`build_cluster`](crate::coordinator::cluster::build_cluster) with
    /// [`ClusterConfig::with_replication`](crate::coordinator::cluster::ClusterConfig::with_replication)
    /// for the assembled path). Shard `i` must be `sets[i]`.
    pub fn start_replicated(
        sets: Vec<ReplicaSet>,
        k: usize,
        vote: VoteConfig,
        failover: FailoverConfig,
    ) -> Orchestrator {
        Self::start_replicated_with_clock(sets, k, vote, failover, Arc::new(SystemClock::new()))
    }

    /// [`start_replicated`](Orchestrator::start_replicated) with an
    /// injected [`Clock`] — hedge, timeout, heartbeat and reconnect
    /// decisions all read this clock, so fault-injection tests pin their
    /// timing with a `MockClock`.
    pub fn start_replicated_with_clock(
        sets: Vec<ReplicaSet>,
        k: usize,
        vote: VoteConfig,
        failover: FailoverConfig,
        clock: Arc<dyn Clock>,
    ) -> Orchestrator {
        let nu = sets.len();
        assert!(nu > 0, "orchestrator needs at least one shard");
        let node_infos: Vec<NodeInfo> = sets.iter().map(|s| s.replicas[0].info()).collect();
        let counters = Arc::new(FailoverCounters::new());
        let ingest = Arc::new(IngestCounters::new());
        let tracer = Arc::new(Tracer::new(Arc::clone(&clock), nu));
        let mut threads = Vec::new();

        // Channels. The reduce channel carries the shard id so the
        // Reducer can order per-shard data deterministically (reply
        // arrival order is scheduler-dependent).
        let (root_tx, root_rx) = channel::<RootRequest>();
        let (fwd_tx, fwd_rx) = channel::<Job>();
        let (reduce_tx, reduce_rx) = channel::<(u64, usize, NodeReply, f64)>();
        let (done_tx, done_rx) = channel::<ReducedQuery>();

        // Shard dispatchers: one thread per shard owning the replica
        // runner threads, hedging and failing over among them.
        let mut shard_tx: Vec<Sender<Job>> = Vec::with_capacity(nu);
        for (shard, set) in sets.into_iter().enumerate() {
            assert_eq!(set.shard_id, shard, "replica sets must arrive in shard order");
            assert!(!set.replicas.is_empty(), "shard {shard} has no replicas");
            let cores = node_infos[shard].cores;
            let (reply_tx, reply_rx) = channel::<(usize, u64, ReplicaOutcome, f64)>();
            let mut runner_tx: Vec<Sender<ReplicaJob>> = Vec::new();
            let mut runners: Vec<JoinHandle<()>> = Vec::new();
            for (idx, mut node) in set.replicas.into_iter().enumerate() {
                let (tx, rx) = channel::<ReplicaJob>();
                runner_tx.push(tx);
                let reply_tx = reply_tx.clone();
                runners.push(
                    std::thread::Builder::new()
                        .name(format!("replica-{shard}-{idx}"))
                        .spawn(move || run_replica(node.as_mut(), idx, rx, reply_tx))
                        .expect("spawn replica runner"),
                );
            }
            drop(reply_tx);
            let (in_tx, in_rx) = channel::<Job>();
            shard_tx.push(in_tx);
            let n_rep = runner_tx.len();
            let reduce_tx = reduce_tx.clone();
            let clock = Arc::clone(&clock);
            let cfg = failover.clone();
            let counters = Arc::clone(&counters);
            let ingest = Arc::clone(&ingest);
            let tracer_d = Arc::clone(&tracer);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("shard-dispatch-{shard}"))
                    .spawn(move || {
                        let next_hb = clock.now_ns().saturating_add(dur_ns(cfg.heartbeat_every));
                        let mut d = ShardDispatcher {
                            shard,
                            cores,
                            clock,
                            cfg,
                            counters,
                            ingest,
                            tracer: tracer_d,
                            health: vec![Health::Up; n_rep],
                            busy: vec![false; n_rep],
                            reconnect: vec![None; n_rep],
                            ingest_log: Vec::new(),
                            runner_tx,
                            reply_rx,
                            reduce_tx,
                            next_seq: 0,
                            next_hb,
                        };
                        d.run(in_rx);
                        drop(d);
                        for h in runners {
                            let _ = h.join();
                        }
                    })
                    .expect("spawn shard dispatcher"),
            );
        }
        drop(reduce_tx);

        // Forwarder: broadcast query jobs to every shard dispatcher;
        // route insert jobs to exactly their target shard.
        threads.push(
            std::thread::Builder::new()
                .name("forwarder".into())
                .spawn(move || {
                    while let Ok(job) = fwd_rx.recv() {
                        match &job {
                            Job::Insert { target, .. } => {
                                if shard_tx[*target].send(job.clone()).is_err() {
                                    return;
                                }
                            }
                            _ => {
                                for tx in &shard_tx {
                                    if tx.send(job.clone()).is_err() {
                                        return;
                                    }
                                }
                            }
                        }
                    }
                })
                .expect("spawn forwarder"),
        );

        // Reducer: fold ν shard replies per qid into the global K-NN.
        let k_red = k;
        threads.push(
            std::thread::Builder::new()
                .name("reducer".into())
                .spawn(move || {
                    let mut pending: HashMap<u64, ReduceAcc> = HashMap::new();
                    while let Ok((qid, shard_id, reply, _dt)) = reduce_rx.recv() {
                        let acc = pending.entry(qid).or_insert_with(|| ReduceAcc {
                            topk: TopK::new(k_red),
                            per_node: Vec::new(),
                            received: 0,
                            partial: false,
                            shed_nodes: 0,
                        });
                        for &n in &reply.neighbors {
                            acc.topk.push_unique(n);
                        }
                        // A merge of partial per-node answers is itself
                        // partial: the flag must survive reduction so the
                        // caller learns recall was traded for the deadline.
                        acc.partial |= reply.partial;
                        acc.shed_nodes += reply.shed as u32;
                        acc.per_node.push((shard_id, reply.comparisons));
                        acc.received += 1;
                        if acc.received == nu {
                            let mut acc = pending.remove(&qid).unwrap();
                            // Deterministic per-shard order regardless of
                            // reply arrival order.
                            acc.per_node.sort_by_key(|(id, _)| *id);
                            let out = ReducedQuery {
                                qid,
                                neighbors: acc.topk.into_sorted(),
                                per_node: acc.per_node.into_iter().map(|(_, c)| c).collect(),
                                partial: acc.partial,
                                shed_nodes: acc.shed_nodes,
                            };
                            if done_tx.send(out).is_err() {
                                return;
                            }
                        }
                    }
                })
                .expect("spawn reducer"),
        );

        // Routed-insert line into the forwarder (the Root never sees
        // inserts — they don't consume qids or reducer slots).
        let ingest_tx = fwd_tx.clone();

        // Root: sequence queries, join reduction results with callers.
        threads.push(
            std::thread::Builder::new()
                .name("root".into())
                .spawn(move || {
                    let finish = |red: ReducedQuery, vote: &VoteConfig, latency_s: f64| {
                        let share = positive_share(&red.neighbors, vote);
                        let max_comparisons = red
                            .per_node
                            .iter()
                            .flat_map(|v| v.iter().copied())
                            .max()
                            .unwrap_or(0);
                        QueryResult {
                            qid: red.qid,
                            neighbors: red.neighbors,
                            positive_share: share,
                            prediction: share >= vote.threshold as f64,
                            max_comparisons,
                            per_node_comparisons: red.per_node,
                            latency_s,
                            partial: red.partial,
                            shed_nodes: red.shed_nodes,
                        }
                    };
                    let mut qid = 0u64;
                    while let Ok(req) = root_rx.recv() {
                        let RootRequest::Batch { qs, nq, budget, class, probe, trace, reply_to } =
                            req;
                        let n = nq;
                        if n == 0 {
                            let _ = reply_to.send(Vec::new());
                            continue;
                        }
                        let t0 = std::time::Instant::now();
                        if fwd_tx
                            .send(Job::Batch {
                                qid0: qid,
                                qs: Arc::new(qs),
                                nq,
                                budget,
                                class,
                                probe,
                                trace,
                            })
                            .is_err()
                        {
                            return;
                        }
                        // Per-qid completion is monotone: every shard
                        // replies to qid i before i + 1, so the reducer
                        // finishes them in order.
                        let mut results = Vec::with_capacity(n);
                        for i in 0..n {
                            let Ok(red) = done_rx.recv() else { return };
                            debug_assert_eq!(red.qid, qid + i as u64);
                            results.push(finish(red, &vote, t0.elapsed().as_secs_f64()));
                        }
                        qid += n as u64;
                        let _ = reply_to.send(results);
                    }
                })
                .expect("spawn root"),
        );

        Orchestrator {
            root_tx,
            ingest_tx,
            admission: None,
            threads,
            node_infos,
            k,
            nu,
            next_ingest: AtomicUsize::new(0),
            ingest,
            failover: counters,
            tracer,
        }
    }

    /// Resolve one query through the full Root → Forwarder → shards →
    /// Reducer → Root pipeline at the default operating point
    /// (equivalent to [`query_spec`] with `QuerySpec::default()`). A dead
    /// or slow shard degrades the answer ([`QueryResult::shed_nodes`]);
    /// only a dropped cluster errors.
    ///
    /// [`query_spec`]: Orchestrator::query_spec
    pub fn query(&self, q: &[f32]) -> Result<QueryResult, ClusterError> {
        self.query_spec(q, &QuerySpec::default())
    }

    /// Resolve one query at an explicit accuracy/latency operating point
    /// (see [`QuerySpec`]). The default spec is bit-identical to
    /// [`query`](Orchestrator::query); `probes`/`recall_hint` widen the
    /// per-table bucket walk, `max_comparisons` caps candidate work
    /// deterministically, `budget` + `policy` bound wall-clock latency.
    pub fn query_spec(&self, q: &[f32], spec: &QuerySpec) -> Result<QueryResult, ClusterError> {
        let mut results = self.query_batch_spec_flat(q.to_vec(), 1, spec)?;
        Ok(results.pop().expect("batch of one reduces to one result"))
    }

    /// Resolve a block of queries in one admission: the whole block is
    /// flattened once and broadcast to every shard, nodes resolve it on
    /// their batched core path, and the Reducer folds replies per query.
    /// Results (neighbors, prediction, comparison counts) are identical
    /// to calling [`query`] per element; `latency_s` of result `i` is
    /// the wall-clock from batch admission to that query's reduction.
    ///
    /// [`query`]: Orchestrator::query
    pub fn query_batch(&self, qs: &[&[f32]]) -> Result<Vec<QueryResult>, ClusterError> {
        let nq = qs.len();
        if nq == 0 {
            return Ok(Vec::new());
        }
        let dim = qs[0].len();
        let mut flat = Vec::with_capacity(nq * dim);
        for q in qs {
            // Hard check: a ragged batch flattened as-if-rectangular would
            // silently scan byte-shifted garbage for every later query.
            assert_eq!(q.len(), dim, "ragged query batch");
            flat.extend_from_slice(q);
        }
        // Caller-formed bulk blocks are analytics by nature: no latency
        // budget, throughput-oriented.
        self.query_batch_spec_flat(flat, nq, &QuerySpec::default().with_class(Class::Analytics))
    }

    /// THE batched serving core: resolve a flat row-major `nq × dim`
    /// block at an explicit operating point. Every other query door
    /// ([`query`], [`query_batch`], [`query_spec`], the admission
    /// dispatcher and the HTTP edge) funnels into this method, so the
    /// knob semantics are defined in exactly one place: [`QuerySpec`].
    ///
    /// Panics if the spec fails [`QuerySpec::validate`] (typed callers
    /// own their specs; the HTTP edge pre-validates into a 400).
    ///
    /// [`query`]: Orchestrator::query
    /// [`query_batch`]: Orchestrator::query_batch
    /// [`query_spec`]: Orchestrator::query_spec
    pub fn query_batch_spec_flat(
        &self,
        qs: Vec<f32>,
        nq: usize,
        spec: &QuerySpec,
    ) -> Result<Vec<QueryResult>, ClusterError> {
        if let Err(e) = spec.validate() {
            panic!("invalid QuerySpec: {e}");
        }
        if nq == 0 {
            return Ok(Vec::new());
        }
        assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
        // Direct-path tracing: mint here (the admission path mints per
        // rider instead), time the round trip on the tracer's clock, and
        // feed the lane histograms. Queue wait is zero by construction —
        // there is no queue on this door. The id only rides the job (and
        // hence the wire, where a nonzero id forces the budget frame)
        // while span collection is on — with it off, wire traffic stays
        // byte-identical to an untraced cluster.
        let lane = spec.class.idx();
        let trace = self.tracer.mint(lane);
        let start_ns = self.tracer.now_ns();
        let (tx, rx) = channel();
        self.root_tx
            .send(RootRequest::Batch {
                qs,
                nq,
                budget: spec.direct_budget(),
                class: spec.class,
                probe: spec.probe_spec(),
                trace: if self.tracer.collecting() { trace } else { 0 },
                reply_to: tx,
            })
            .map_err(|_| ClusterError::Shutdown)?;
        let mut results = rx.recv().map_err(|_| ClusterError::Shutdown)?;
        if spec.k > 0 {
            for r in &mut results {
                r.neighbors.truncate(spec.k);
            }
        }
        let end_ns = self.tracer.now_ns();
        let e2e_us = end_ns.saturating_sub(start_ns) / 1_000;
        self.tracer.span(trace, "service", start_ns, end_ns);
        self.tracer.record_lane(lane, 0, e2e_us, e2e_us);
        let partial = results.iter().any(|r| r.partial);
        let shed = results.iter().any(|r| r.shed_nodes > 0);
        self.tracer.finish(trace, lane, e2e_us, partial, shed);
        Ok(results)
    }

    /// Flat-buffer variant of [`query_batch`] with positional knobs.
    ///
    /// [`query_batch`]: Orchestrator::query_batch
    #[deprecated(note = "use query_batch_spec_flat with a QuerySpec")]
    pub fn query_batch_flat(
        &self,
        qs: Vec<f32>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Result<Vec<QueryResult>, ClusterError> {
        if nq == 0 {
            return Ok(Vec::new());
        }
        assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
        let (tx, rx) = channel();
        self.root_tx
            .send(RootRequest::Batch {
                qs,
                nq,
                budget,
                class,
                probe: ProbeSpec::BASELINE,
                trace: 0,
                reply_to: tx,
            })
            .map_err(|_| ClusterError::Shutdown)?;
        rx.recv().map_err(|_| ClusterError::Shutdown)
    }

    /// Append a batch of labeled points to the live cluster (`points`
    /// row-major `labels.len() × dim`), ingest attributed to
    /// [`Class::Monitor`] — live bedside streams are the default
    /// ingester. See [`insert_batch_class`].
    ///
    /// [`insert_batch_class`]: Orchestrator::insert_batch_class
    pub fn insert_batch(
        &self,
        points: &[f32],
        labels: &[bool],
    ) -> Result<InsertOutcome, ClusterError> {
        self.insert_batch_class(points, labels, Class::Monitor)
    }

    /// Append a batch of labeled points, attributing the ingest to an
    /// explicit scheduling class (monitor streams vs analytics
    /// backfills — the per-lane `inserted` counter in
    /// [`LaneStats`](crate::coordinator::admission::LaneStats) when the
    /// admission layer is installed).
    ///
    /// Routing: batches go to ONE shard each, round-robin — unlike
    /// queries, which broadcast; a point lives on exactly one shard. On
    /// the shard, the batch fans out to every live replica so replicas
    /// stay interchangeable; [`InsertOutcome::replicas_acked`] reports
    /// how many made it durable, and zero acks is
    /// [`ClusterError::ShardUnavailable`] — never a silent drop.
    /// Inserts travel Forwarder → shard dispatcher directly (no Root
    /// sequencing, no qids), so a sustained ingest stream interleaves
    /// with queries instead of serializing behind them; per shard, the
    /// dispatcher's inbox orders inserts against query jobs, so a query
    /// submitted after this call returns observes the points. Requires
    /// live nodes
    /// ([`build_live_cluster`](crate::coordinator::cluster::build_live_cluster));
    /// inserts to batch-built nodes error rather than drop data.
    pub fn insert_batch_class(
        &self,
        points: &[f32],
        labels: &[bool],
        class: Class,
    ) -> Result<InsertOutcome, ClusterError> {
        let n = labels.len();
        assert!(n > 0, "empty insert batch");
        assert_eq!(points.len() % n, 0, "insert block not n × dim");
        let target = self.next_ingest.fetch_add(1, Ordering::Relaxed) % self.nu;
        let (tx, rx) = channel();
        self.ingest_tx
            .send(Job::Insert {
                target,
                points: Arc::new(points.to_vec()),
                labels: Arc::new(labels.to_vec()),
                reply: tx,
            })
            .map_err(|_| ClusterError::Shutdown)?;
        let shard_ack = rx.recv().map_err(|_| ClusterError::Shutdown)??;
        let r = shard_ack.reply;
        self.ingest.record_batch(r.accepted);
        self.ingest.record_seals(r.sealed_now);
        if let Some(q) = &self.admission {
            q.note_ingest(class, r.accepted);
        }
        Ok(InsertOutcome {
            node: target,
            accepted: r.accepted,
            node_total: r.total,
            sealed_now: r.sealed_now,
            sealed_total: r.sealed_total,
            replicas_acked: shard_ack.replicas_acked,
        })
    }

    /// Cluster-wide ingest telemetry snapshot.
    pub fn ingest_stats(&self) -> IngestStats {
        self.ingest.snapshot()
    }

    /// Hedge / failover / reconnect telemetry snapshot, aggregated over
    /// every shard dispatcher.
    pub fn failover_stats(&self) -> FailoverStats {
        self.failover.snapshot()
    }

    /// Install the deadline-aware admission layer (see
    /// [`crate::coordinator::admission`]): independent callers
    /// [`submit`](Orchestrator::submit) single queries with latency
    /// budgets and a cutter thread coalesces them into
    /// [`query_batch`](Orchestrator::query_batch)-shaped blocks, cutting
    /// on fill or on the earliest deadline. Replaces (and drains) any
    /// previously installed queue.
    pub fn enable_admission(&mut self, cfg: AdmissionConfig) {
        // Drain the old queue before the new one starts competing for
        // the root channel.
        self.admission = None;
        let dispatch = root_dispatcher(self.root_tx.clone());
        // The queue shares the orchestrator's tracer (and hence its
        // clock): per-rider queue-wait / service spans land in the same
        // histograms as direct-path queries.
        self.admission = Some(AdmissionQueue::start_traced(cfg, dispatch, self.tracer()));
    }

    /// The cluster's [`Tracer`]: per-lane and per-shard latency
    /// histograms (always on), opt-in span collection, and the
    /// slow-query ring. The serving edge exposes it at `GET /metrics`
    /// and `GET /v1/debug/slow`.
    pub fn tracer(&self) -> Arc<Tracer> {
        Arc::clone(&self.tracer)
    }

    /// Admit one [`Class::Monitor`] query with a latency budget; returns
    /// a [`Ticket`] whose [`wait`](Ticket::wait) yields the same result
    /// [`query`] would (bit-identical reduction — the admission layer
    /// only changes *when* work is dispatched, never what it computes)
    /// — except under an enforcing
    /// [`BudgetPolicy`](crate::coordinator::admission::BudgetPolicy)
    /// (`PartialResults`/`Shed`), where a blown budget yields a
    /// prefix-of-the-full answer with [`QueryResult::partial`] set
    /// instead of a late complete one.
    /// Requires [`enable_admission`](Orchestrator::enable_admission).
    /// Bulk callers should use
    /// [`submit_class`](Orchestrator::submit_class) with
    /// [`Class::Analytics`] so they never delay a monitor past its
    /// budget.
    ///
    /// [`query`]: Orchestrator::query
    #[deprecated(note = "use submit_spec with a QuerySpec")]
    pub fn submit(&self, q: &[f32], budget: Duration) -> Result<Ticket, AdmissionError> {
        self.submit_spec(q, &QuerySpec::default().with_budget(budget))
    }

    /// Admit one query into an explicit scheduling lane (see
    /// [`Class`]); same bit-identical-result contract as
    /// [`submit_spec`](Orchestrator::submit_spec).
    #[deprecated(note = "use submit_spec with a QuerySpec")]
    pub fn submit_class(
        &self,
        q: &[f32],
        budget: Duration,
        class: Class,
    ) -> Result<Ticket, AdmissionError> {
        self.submit_spec(q, &QuerySpec::default().with_budget(budget).with_class(class))
    }

    /// Admit one query at an explicit operating point ([`QuerySpec`]):
    /// `class` picks the scheduling lane, `budget` the cut deadline
    /// (`None` = ride fill/aged/drain cuts only), `policy` the node-side
    /// enforcement (strictest rider governs a shared cut), and the probe
    /// knobs travel with the cut to every node. The default spec with a
    /// budget reproduces the old `submit` exactly. Requires
    /// [`enable_admission`](Orchestrator::enable_admission).
    pub fn submit_spec(&self, q: &[f32], spec: &QuerySpec) -> Result<Ticket, AdmissionError> {
        self.admission
            .as_ref()
            .expect("call enable_admission before submit_spec")
            .submit_spec(q, spec)
    }

    /// The installed admission queue, if any (stats, `try_submit`).
    pub fn admission(&self) -> Option<&AdmissionQueue> {
        self.admission.as_ref()
    }

    pub fn num_nodes(&self) -> usize {
        self.nu
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn node_infos(&self) -> &[NodeInfo] {
        &self.node_infos
    }

    /// Total processors (pν) across the cluster (per shard, not per
    /// replica — replicas duplicate work for availability, they don't
    /// partition it).
    pub fn total_processors(&self) -> usize {
        self.node_infos.iter().map(|i| i.cores).sum()
    }
}

impl Drop for Orchestrator {
    fn drop(&mut self) {
        // The admission cutter holds a root_tx clone, so it must drain
        // and exit FIRST or the root thread would never see EOF.
        self.admission = None;
        // Closing root_tx AND the ingest line cascades: root exits, the
        // forwarder inbox loses its last sender, shard dispatchers see
        // EOF, replica runners exit, the reducer sees EOF.
        let (dead_tx, _) = channel();
        let _ = std::mem::replace(&mut self.root_tx, dead_tx);
        let (dead_ingest, _) = channel();
        let _ = std::mem::replace(&mut self.ingest_tx, dead_ingest);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Replica runner: executes jobs from its inbox strictly in order and
/// reports `(replica, seq, outcome, secs)` — the dispatcher interprets
/// outcomes; the runner never retries or routes.
fn run_replica(
    node: &mut dyn NodeHandle,
    idx: usize,
    rx: Receiver<ReplicaJob>,
    reply_tx: Sender<(usize, u64, ReplicaOutcome, f64)>,
) {
    while let Ok(rj) = rx.recv() {
        let t0 = std::time::Instant::now();
        let (seq, outcome) = match rj {
            ReplicaJob::Run { seq, job } => {
                let out = match job {
                    Job::Batch { qid0, qs, nq, budget, class, probe, trace } => {
                        node.query_batch_traced(qs, nq, budget, class, probe, trace).map(|rs| {
                            rs.into_iter()
                                .enumerate()
                                .map(|(i, r)| (qid0 + i as u64, r))
                                .collect()
                        })
                    }
                    Job::Insert { .. } => unreachable!("inserts travel as ReplicaJob::Insert"),
                };
                (seq, ReplicaOutcome::Queries(out))
            }
            ReplicaJob::Insert { seq, points, labels } => {
                (seq, ReplicaOutcome::Insert(node.insert_batch(&points, &labels)))
            }
            ReplicaJob::Heartbeat { seq } => (seq, ReplicaOutcome::Heartbeat(node.heartbeat())),
            ReplicaJob::Reconnect { seq, backlog } => {
                // Re-dial, then replay the shard's acked inserts in their
                // original order: the rebuilt live store assigns the same
                // ids its peers did, so the replica rejoins bit-identical
                // instead of serving an empty shard.
                let out = node.reconnect().and_then(|()| {
                    let mut replayed = 0u64;
                    for (points, labels) in &backlog {
                        node.insert_batch(points, labels)?;
                        replayed += 1;
                    }
                    Ok(replayed)
                });
                (seq, ReplicaOutcome::Reconnect(out))
            }
        };
        if reply_tx.send((idx, seq, outcome, t0.elapsed().as_secs_f64())).is_err() {
            break;
        }
    }
}

/// Per-shard hedged dispatcher state (one per shard, owning its replica
/// runners). See the module header for the policy it implements.
struct ShardDispatcher {
    shard: usize,
    /// Replica-0 core count — the shape of a synthesized shed reply's
    /// per-core comparison vector.
    cores: usize,
    clock: Arc<dyn Clock>,
    cfg: FailoverConfig,
    counters: Arc<FailoverCounters>,
    ingest: Arc<IngestCounters>,
    tracer: Arc<Tracer>,
    health: Vec<Health>,
    /// Replica has an unanswered job in its inbox (stale or current).
    busy: Vec<bool>,
    /// `Down` replicas' reconnect schedule: `(attempt, due_ns)`; the due
    /// time is `u64::MAX` while an attempt is in flight.
    reconnect: Vec<Option<(u32, u64)>>,
    /// Every insert batch at least one replica acked, in arrival order —
    /// the shard's recovery log. A reconnecting replica replays it after
    /// re-dialing (its rebuilt store starts empty), so it rejoins with
    /// the same points and ids as its peers. Entries are `Arc` pairs
    /// shared with the original jobs; compaction (sealed-segment snapshot
    /// shipping) is a roadmap item.
    ingest_log: Vec<(Arc<Vec<f32>>, Arc<Vec<bool>>)>,
    runner_tx: Vec<Sender<ReplicaJob>>,
    reply_rx: Receiver<(usize, u64, ReplicaOutcome, f64)>,
    reduce_tx: Sender<(u64, usize, NodeReply, f64)>,
    next_seq: u64,
    next_hb: u64,
}

impl ShardDispatcher {
    fn run(&mut self, inbox: Receiver<Job>) {
        loop {
            self.drain_stale();
            self.fire_duties();
            match inbox.recv_timeout(self.idle_wait()) {
                Ok(Job::Batch { qid0, qs, nq, budget, class, probe, trace }) => self.resolve(
                    qid0,
                    nq,
                    Job::Batch { qid0, qs, nq, budget, class, probe, trace },
                ),
                Ok(Job::Insert { points, labels, reply, .. }) => {
                    self.insert(points, labels, reply)
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    fn take_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Replicas eligible for a new query, best first: `Up` before
    /// `Suspect`, idle before busy, then lowest index (deterministic).
    fn candidates(&self) -> Vec<usize> {
        let mut c: Vec<usize> =
            (0..self.health.len()).filter(|&i| self.health[i] != Health::Down).collect();
        c.sort_by_key(|&i| (self.health[i] == Health::Suspect, self.busy[i], i));
        c
    }

    /// Dispatch `job` to the first remaining candidate whose runner is
    /// still accepting work; returns the chosen replica.
    fn try_dispatch(&mut self, remaining: &mut Vec<usize>, seq: u64, job: &Job) -> Option<usize> {
        while !remaining.is_empty() {
            let idx = remaining.remove(0);
            if self.health[idx] == Health::Down {
                continue;
            }
            if self.runner_tx[idx].send(ReplicaJob::Run { seq, job: job.clone() }).is_ok() {
                self.busy[idx] = true;
                return Some(idx);
            }
        }
        None
    }

    /// Hedged resolution of one query job covering qids
    /// `[qid0, qid0 + nq)`: primary dispatch, hedge after
    /// `cfg.hedge_after`, failover on replica error, synthesized shed on
    /// total loss or `cfg.request_timeout` — exactly one reply per qid
    /// reaches the Reducer.
    fn resolve(&mut self, qid0: u64, nq: usize, job: Job) {
        let trace = match &job {
            Job::Batch { trace, .. } => *trace,
            Job::Insert { .. } => 0,
        };
        let seq = self.take_seq();
        let mut remaining = self.candidates();
        let mut inflight: Vec<usize> = Vec::new();
        match self.try_dispatch(&mut remaining, seq, &job) {
            Some(p) => inflight.push(p),
            None => {
                self.synth_shed(qid0, nq);
                return;
            }
        }
        let mut hedged = false;
        let mut hedge_replica: Option<usize> = None;
        let t0 = self.clock.now_ns();
        let hedge_at = t0.saturating_add(dur_ns(self.cfg.hedge_after));
        let deadline = t0.saturating_add(dur_ns(self.cfg.request_timeout));
        loop {
            let now = self.clock.now_ns();
            if now >= deadline {
                // Stragglers aren't dead, just too slow to wait for.
                for &r in &inflight {
                    if self.health[r] == Health::Up {
                        self.health[r] = Health::Suspect;
                    }
                }
                self.synth_shed(qid0, nq);
                return;
            }
            let next_event = if hedged { deadline } else { hedge_at.min(deadline) };
            let wait = Duration::from_nanos(next_event.saturating_sub(now))
                .min(RESOLVE_POLL);
            match self.reply_rx.recv_timeout(wait) {
                Ok((idx, rseq, outcome, dt)) => {
                    if rseq != seq {
                        self.absorb(idx, outcome);
                        continue;
                    }
                    self.busy[idx] = false;
                    match outcome {
                        ReplicaOutcome::Queries(Ok(replies)) => {
                            self.on_ok(idx);
                            if hedge_replica == Some(idx) {
                                self.counters.record_hedge_win();
                            }
                            // Shard distributions, once per batch: the
                            // network round trip (runner wall time) and
                            // the node's own scan span (batch-wide, so
                            // every reply of the batch carries the same
                            // value — record the first).
                            self.tracer.record_shard_net(self.shard, (dt * 1e6) as u64);
                            if let Some((_, first)) = replies.first() {
                                self.tracer.record_shard_scan(self.shard, first.scan_ns / 1_000);
                                if trace != 0 {
                                    let span = NodeSpan {
                                        shard: self.shard,
                                        scan_ns: first.scan_ns,
                                        comparisons: replies
                                            .iter()
                                            .flat_map(|(_, r)| r.comparisons.iter().copied())
                                            .sum(),
                                        tables: first.tables,
                                        partial: replies.iter().any(|(_, r)| r.partial),
                                        shed: replies.iter().any(|(_, r)| r.shed),
                                    };
                                    self.tracer.node_span(trace, span);
                                }
                            }
                            for (qid, reply) in replies {
                                let _ = self.reduce_tx.send((qid, self.shard, reply, dt));
                            }
                            return;
                        }
                        ReplicaOutcome::Queries(Err(_)) => {
                            self.mark_down(idx);
                            inflight.retain(|&r| r != idx);
                            if hedge_replica == Some(idx) {
                                hedge_replica = None;
                            }
                            if let Some(next) = self.try_dispatch(&mut remaining, seq, &job) {
                                self.counters.record_failover();
                                inflight.push(next);
                            } else if inflight.is_empty() {
                                self.synth_shed(qid0, nq);
                                return;
                            }
                        }
                        other => self.absorb(idx, other),
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if !hedged && self.clock.now_ns() >= hedge_at {
                        hedged = true;
                        if let Some(h) = self.try_dispatch(&mut remaining, seq, &job) {
                            self.counters.record_hedge();
                            self.tracer.note_hedge(trace);
                            hedge_replica = Some(h);
                            if let Some(&p) = inflight.first() {
                                if self.health[p] == Health::Up {
                                    self.health[p] = Health::Suspect;
                                }
                            }
                            inflight.push(h);
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
    }

    /// Replicated insert: fan to every live replica, collect acks until
    /// `cfg.request_timeout`. One ack suffices for durability; the total
    /// ack count travels back to the caller.
    fn insert(
        &mut self,
        points: Arc<Vec<f32>>,
        labels: Arc<Vec<bool>>,
        reply: Sender<Result<ShardInsert, ClusterError>>,
    ) {
        let seq = self.take_seq();
        let mut outstanding: Vec<usize> = Vec::new();
        for i in 0..self.runner_tx.len() {
            if self.health[i] == Health::Down {
                continue;
            }
            let rj = ReplicaJob::Insert {
                seq,
                points: Arc::clone(&points),
                labels: Arc::clone(&labels),
            };
            if self.runner_tx[i].send(rj).is_ok() {
                self.busy[i] = true;
                outstanding.push(i);
            }
        }
        if outstanding.is_empty() {
            let _ = reply.send(Err(ClusterError::ShardUnavailable { shard: self.shard }));
            return;
        }
        let deadline = self.clock.now_ns().saturating_add(dur_ns(self.cfg.request_timeout));
        let mut first: Option<InsertReply> = None;
        let mut acked = 0u32;
        while !outstanding.is_empty() {
            let now = self.clock.now_ns();
            if now >= deadline {
                for &r in &outstanding {
                    if self.health[r] == Health::Up {
                        self.health[r] = Health::Suspect;
                    }
                }
                break;
            }
            let wait =
                Duration::from_nanos(deadline.saturating_sub(now)).min(RESOLVE_POLL);
            match self.reply_rx.recv_timeout(wait) {
                Ok((idx, rseq, outcome, _dt)) => {
                    if rseq != seq {
                        self.absorb(idx, outcome);
                        continue;
                    }
                    self.busy[idx] = false;
                    outstanding.retain(|&r| r != idx);
                    match outcome {
                        ReplicaOutcome::Insert(Ok(r)) => {
                            self.on_ok(idx);
                            acked += 1;
                            if first.is_none() {
                                first = Some(r);
                            }
                        }
                        ReplicaOutcome::Insert(Err(_)) => self.mark_down(idx),
                        other => self.absorb(idx, other),
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if first.is_some() {
            // The batch is durable on this shard: log it so replicas
            // that were down (and missed the fan-out) can replay it on
            // reconnect.
            self.ingest_log.push((points, labels));
        }
        let _ = reply.send(match first {
            Some(r) => Ok(ShardInsert { reply: r, replicas_acked: acked }),
            None => Err(ClusterError::ShardUnavailable { shard: self.shard }),
        });
    }

    /// Emit the shed reply every query of a lost job — the same shape a
    /// node-side `BudgetPolicy::Shed` produces, so reduction and caller
    /// semantics are identical whether the node or the dispatcher shed.
    fn synth_shed(&mut self, qid0: u64, nq: usize) {
        self.counters.record_synthesized_shed();
        for i in 0..nq {
            let qid = qid0 + i as u64;
            let reply = NodeReply {
                qid,
                neighbors: Vec::new(),
                comparisons: vec![0u64; self.cores],
                inner_probes: 0,
                scan_ns: 0,
                tables: 0,
                partial: true,
                shed: true,
            };
            let _ = self.reduce_tx.send((qid, self.shard, reply, 0.0));
        }
    }

    /// Process an outcome that does not complete the current request: a
    /// hedge loser's late reply, a heartbeat ack, a reconnect result.
    /// Health still updates — a late success proves the replica lives.
    fn absorb(&mut self, idx: usize, outcome: ReplicaOutcome) {
        self.busy[idx] = false;
        match outcome {
            ReplicaOutcome::Queries(Ok(_)) | ReplicaOutcome::Insert(Ok(_)) => self.on_ok(idx),
            ReplicaOutcome::Heartbeat(Ok(hb)) => {
                self.on_ok(idx);
                // The heartbeat doubles as the cluster-level seal poll:
                // age-expired seals on quiet live nodes surface here.
                if hb.live && hb.sealed_now > 0 {
                    self.ingest.record_seals(hb.sealed_now);
                }
            }
            ReplicaOutcome::Reconnect(Ok(replayed)) => {
                self.counters.record_reconnect();
                if replayed as usize == self.ingest_log.len() {
                    self.reconnect[idx] = None;
                    if self.health[idx] == Health::Down {
                        self.health[idx] = Health::Suspect;
                        self.counters.record_down_recovered();
                    }
                } else {
                    // Batches landed while the replay was in flight: the
                    // transport lives, but the replica is still behind
                    // its peers. Re-dial immediately — the next attempt
                    // replays the longer log from scratch.
                    let attempt = self.reconnect[idx].map(|(a, _)| a).unwrap_or(0);
                    self.reconnect[idx] = Some((attempt, self.clock.now_ns()));
                }
            }
            ReplicaOutcome::Queries(Err(_))
            | ReplicaOutcome::Insert(Err(_))
            | ReplicaOutcome::Heartbeat(Err(_)) => self.mark_down(idx),
            ReplicaOutcome::Reconnect(Err(_)) => {
                let attempt = self.reconnect[idx].map(|(a, _)| a + 1).unwrap_or(1);
                let due = self
                    .clock
                    .now_ns()
                    .saturating_add(dur_ns(self.cfg.reconnect_delay(attempt)));
                self.reconnect[idx] = Some((attempt, due));
            }
        }
    }

    fn on_ok(&mut self, idx: usize) {
        if self.health[idx] == Health::Down {
            // A late reply from a replica we had written off: it lives.
            self.counters.record_down_recovered();
        }
        self.health[idx] = Health::Up;
        self.reconnect[idx] = None;
    }

    fn mark_down(&mut self, idx: usize) {
        if self.health[idx] != Health::Down {
            self.health[idx] = Health::Down;
            self.counters.record_down();
            let due = self.clock.now_ns().saturating_add(dur_ns(self.cfg.reconnect_delay(0)));
            self.reconnect[idx] = Some((0, due));
        }
    }

    /// Idle duties between jobs: fire heartbeats on schedule, fire due
    /// reconnect attempts for `Down` replicas.
    fn fire_duties(&mut self) {
        let now = self.clock.now_ns();
        if now >= self.next_hb {
            for i in 0..self.runner_tx.len() {
                if self.health[i] == Health::Down || self.busy[i] {
                    continue;
                }
                let seq = self.take_seq();
                if self.runner_tx[i].send(ReplicaJob::Heartbeat { seq }).is_ok() {
                    self.busy[i] = true;
                    self.counters.record_heartbeat();
                }
            }
            self.next_hb = now.saturating_add(dur_ns(self.cfg.heartbeat_every));
        }
        for i in 0..self.runner_tx.len() {
            if let Some((attempt, due)) = self.reconnect[i] {
                if self.health[i] == Health::Down && !self.busy[i] && now >= due {
                    let seq = self.take_seq();
                    let backlog = self.ingest_log.clone();
                    if self.runner_tx[i].send(ReplicaJob::Reconnect { seq, backlog }).is_ok() {
                        self.busy[i] = true;
                        self.counters.record_reconnect_attempt();
                        // Park the schedule while the attempt is in
                        // flight; its outcome re-arms it.
                        self.reconnect[i] = Some((attempt, u64::MAX));
                    }
                }
            }
        }
    }

    fn drain_stale(&mut self) {
        while let Ok((idx, _seq, outcome, _dt)) = self.reply_rx.try_recv() {
            self.absorb(idx, outcome);
        }
    }

    /// Time until the next heartbeat or reconnect duty, capped so a
    /// frozen `MockClock` advanced by a test is noticed promptly.
    fn idle_wait(&self) -> Duration {
        let now = self.clock.now_ns();
        let mut next = self.next_hb;
        for r in self.reconnect.iter().flatten() {
            next = next.min(r.1);
        }
        Duration::from_nanos(next.saturating_sub(now)).min(IDLE_POLL)
    }
}

struct ReduceAcc {
    topk: TopK,
    /// `(shard_id, per-core comparisons)` — sorted by shard on completion.
    per_node: Vec<(usize, Vec<u64>)>,
    received: usize,
    /// Any node answered partially under budget enforcement.
    partial: bool,
    /// Shards whose reply was a shed (node-side or synthesized).
    shed_nodes: u32,
}

struct ReducedQuery {
    qid: u64,
    neighbors: Vec<Neighbor>,
    per_node: Vec<Vec<u64>>,
    partial: bool,
    shed_nodes: u32,
}
