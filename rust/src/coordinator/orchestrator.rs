//! The Orchestrator (paper Figure 1, §3): Root, Forwarder and Reducer
//! processes coordinating ν SLSH nodes.
//!
//! * **Root** — the public API; coordinates query resolution (and, at
//!   construction time, shard assignment + hash-spec broadcast, done in
//!   [`crate::coordinator::cluster`]).
//! * **Forwarder** — broadcasts each query to every node.
//! * **Reducer** — gathers the ν node-local K-NN sets and keeps the K
//!   closest (reduction), then the Root turns them into the prediction.
//!
//! All three are real threads connected by channels, mirroring the cloud
//! deployment's processes; nodes are [`NodeHandle`]s so the same
//! Orchestrator drives in-process thread-group nodes and remote TCP nodes.
//!
//! Queries enter through three doors: [`Orchestrator::query`] (one query,
//! the paper's ICU latency model), [`Orchestrator::query_batch`] (a
//! caller-formed block), and — once
//! [`Orchestrator::enable_admission`] has installed the deadline-aware
//! admission layer — [`Orchestrator::submit`], which coalesces
//! *independent* callers into shared cuts under per-request latency
//! budgets (see [`crate::coordinator::admission`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::admission::{
    root_dispatcher, AdmissionConfig, AdmissionError, AdmissionQueue, Budget, Class, Ticket,
};
use crate::knn::heap::{Neighbor, TopK};
use crate::knn::predict::{positive_share, VoteConfig};
use crate::node::node::{InsertReply, NodeInfo, NodeReply};
use crate::runtime::service::{IngestCounters, IngestStats};

/// Sentinel budget for batches that carry no latency deadline (direct
/// [`Orchestrator::query_batch`] calls, as opposed to admission cuts).
pub const NO_BUDGET: u64 = u64::MAX;

/// Abstraction over a node the Forwarder can reach (in-process thread
/// group or TCP-remote process).
pub trait NodeHandle: Send {
    fn node_id(&self) -> usize;
    fn info(&self) -> NodeInfo;
    fn query(&mut self, q: &[f32]) -> NodeReply;

    /// Resolve a block of `nq` queries (`qs` row-major `nq × dim` — one
    /// shared flat buffer end to end, so batching adds no per-query or
    /// per-node allocations). The default falls back to per-query round
    /// trips; in-process and TCP nodes override it to ship the whole
    /// block at once and ride the cores' batched resolution path
    /// (batched hashing + reused scratch arena).
    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Vec<NodeReply> {
        if nq == 0 {
            return Vec::new();
        }
        debug_assert_eq!(qs.len() % nq, 0);
        let dim = qs.len() / nq;
        qs.chunks_exact(dim).map(|q| self.query(q)).collect()
    }

    /// Batch resolution carrying the admission cut's [`Budget`] — the
    /// remaining latency budget (µs until the batch's most urgent
    /// deadline, computed once at dispatch; [`NO_BUDGET`] when the batch
    /// has none) plus the enforcement policy — and the cut's scheduling
    /// class ([`Class::Monitor`] if any monitor rides it). The default
    /// ignores both — the orchestrator-side cutter already made the cut —
    /// but real nodes enforce the budget (early-exit/shed per policy) and
    /// transports (TCP) ship budget, policy and class with the frame so
    /// the far side enforces the same deadline and attributes overruns to
    /// the right lane.
    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        _budget: Budget,
        _class: Class,
    ) -> Vec<NodeReply> {
        self.query_batch(qs, nq)
    }

    /// Append a batch of labeled points to this node's live index
    /// (`points` row-major `labels.len() × dim`), returning once every
    /// core has indexed them. Only live nodes
    /// ([`LocalNode::spawn_live`](crate::node::node::LocalNode::spawn_live),
    /// [`RemoteNode::connect_live`](crate::net::tcp::RemoteNode::connect_live))
    /// support inserts; the default panics so a misrouted insert fails
    /// loudly instead of silently dropping ICU data.
    fn insert_batch(&mut self, _points: &[f32], _labels: &[bool]) -> InsertReply {
        panic!("node {} does not accept online inserts (live nodes only)", self.node_id());
    }
}

impl NodeHandle for crate::node::node::LocalNode {
    fn node_id(&self) -> usize {
        crate::node::node::LocalNode::node_id(self)
    }
    fn info(&self) -> NodeInfo {
        crate::node::node::LocalNode::info(self).clone()
    }
    fn query(&mut self, q: &[f32]) -> NodeReply {
        crate::node::node::LocalNode::query(self, q)
    }
    fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Vec<NodeReply> {
        crate::node::node::LocalNode::query_batch(self, qs, nq)
    }
    fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Vec<NodeReply> {
        crate::node::node::LocalNode::query_batch_budget(self, qs, nq, budget, class)
    }
    fn insert_batch(&mut self, points: &[f32], labels: &[bool]) -> InsertReply {
        crate::node::node::LocalNode::insert_batch(self, points, labels)
    }
}

/// Final, reduced answer for one query.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub qid: u64,
    /// Global K-NN across all nodes.
    pub neighbors: Vec<Neighbor>,
    /// Weighted-vote positive share and thresholded prediction.
    pub positive_share: f64,
    pub prediction: bool,
    /// Max comparisons across ALL processors (the paper's speed metric).
    pub max_comparisons: u64,
    /// Per-node, per-core comparison counts, in ascending node-id order
    /// (deterministic regardless of reply arrival order).
    pub per_node_comparisons: Vec<Vec<u64>>,
    /// Wall-clock latency of the full round trip (seconds).
    pub latency_s: f64,
    /// True when at least one node answered from an incomplete scan under
    /// budget enforcement (includes sheds): `neighbors` covers a prefix
    /// of the cluster's tables, not all of them — recall was traded for
    /// the deadline. Always `false` under `BudgetPolicy::LogOnly` and for
    /// un-budgeted queries.
    pub partial: bool,
    /// Nodes that shed this query's batch outright (budget already spent
    /// on arrival under `BudgetPolicy::Shed` — zero scan work done).
    pub shed_nodes: u32,
}

/// Cluster-level outcome of one routed insert batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertOutcome {
    /// Node the batch was routed to (round-robin).
    pub node: usize,
    /// Points appended.
    pub accepted: u64,
    /// That node's total points afterwards.
    pub node_total: u64,
    /// Segments the batch caused to seal.
    pub sealed_now: u64,
    /// That node's total sealed segments afterwards.
    pub sealed_total: u64,
}

#[derive(Clone)]
enum Job {
    Single { qid: u64, q: Arc<Vec<f32>> },
    /// Flat row-major `nq × dim` block; query `i` has id `qid0 + i`.
    /// `budget` is the admission cut's remaining latency budget plus
    /// enforcement policy ([`Budget::none`] for caller-formed blocks);
    /// `class` is the cut's scheduling class (monitor if any monitor
    /// rides it).
    Batch { qid0: u64, qs: Arc<Vec<f32>>, nq: usize, budget: Budget, class: Class },
    /// Online insert, ROUTED to node `target` (never broadcast — each
    /// point lives on exactly one shard); the node runner acks straight
    /// to the caller through `reply`, bypassing the query Reducer.
    Insert {
        target: usize,
        points: Arc<Vec<f32>>,
        labels: Arc<Vec<bool>>,
        reply: Sender<InsertReply>,
    },
}

pub(crate) enum RootRequest {
    Single(Vec<f32>, Sender<QueryResult>),
    /// Flat row-major `nq × dim` block.
    Batch {
        qs: Vec<f32>,
        nq: usize,
        budget: Budget,
        class: Class,
        reply_to: Sender<Vec<QueryResult>>,
    },
}

/// Orchestrator over ν nodes.
pub struct Orchestrator {
    root_tx: Sender<RootRequest>,
    /// Direct line to the Forwarder for routed (non-broadcast) work:
    /// online inserts skip the Root's query sequencing entirely, so a
    /// sustained ingest stream never serializes behind queries.
    ingest_tx: Sender<Job>,
    /// Deadline-aware admission layer (see [`Orchestrator::enable_admission`]).
    admission: Option<AdmissionQueue>,
    threads: Vec<JoinHandle<()>>,
    node_infos: Vec<NodeInfo>,
    k: usize,
    nu: usize,
    /// Round-robin insert-routing cursor.
    next_ingest: AtomicUsize,
    /// Cluster-wide ingest telemetry (batches, points, seals).
    ingest: Arc<IngestCounters>,
}

impl Orchestrator {
    /// Wire Root → Forwarder → node runners → Reducer → Root and start
    /// all threads.
    pub fn start(nodes: Vec<Box<dyn NodeHandle>>, k: usize, vote: VoteConfig) -> Orchestrator {
        let nu = nodes.len();
        assert!(nu > 0, "orchestrator needs at least one node");
        let node_infos: Vec<NodeInfo> = nodes.iter().map(|n| n.info()).collect();
        let mut threads = Vec::new();

        // Channels. The reduce channel carries the node id so the Reducer
        // can order per-node data deterministically (reply arrival order
        // is scheduler-dependent).
        let (root_tx, root_rx) = channel::<RootRequest>();
        let (fwd_tx, fwd_rx) = channel::<Job>();
        let (reduce_tx, reduce_rx) = channel::<(u64, usize, NodeReply, f64)>();
        let (done_tx, done_rx) = channel::<ReducedQuery>();

        // Node runners: one thread per node, each with its own inbox.
        let mut node_tx: Vec<Sender<Job>> = Vec::with_capacity(nu);
        for mut node in nodes {
            let (tx, rx) = channel::<Job>();
            node_tx.push(tx);
            let reduce_tx = reduce_tx.clone();
            let node_id = node.node_id();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("node-runner-{node_id}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            match job {
                                Job::Single { qid, q } => {
                                    let t0 = std::time::Instant::now();
                                    let reply = node.query(&q);
                                    let dt = t0.elapsed().as_secs_f64();
                                    if reduce_tx.send((qid, node_id, reply, dt)).is_err() {
                                        break;
                                    }
                                }
                                Job::Batch { qid0, qs, nq, budget, class } => {
                                    let t0 = std::time::Instant::now();
                                    let replies =
                                        node.query_batch_budget(qs, nq, budget, class);
                                    let dt = t0.elapsed().as_secs_f64();
                                    debug_assert_eq!(replies.len(), nq);
                                    let mut dead = false;
                                    for (i, reply) in replies.into_iter().enumerate() {
                                        if reduce_tx
                                            .send((qid0 + i as u64, node_id, reply, dt))
                                            .is_err()
                                        {
                                            dead = true;
                                            break;
                                        }
                                    }
                                    if dead {
                                        break;
                                    }
                                }
                                Job::Insert { points, labels, reply, .. } => {
                                    let r = node.insert_batch(&points, &labels);
                                    // A dropped reply just means the
                                    // caller gave up waiting; the insert
                                    // itself is already durable.
                                    let _ = reply.send(r);
                                }
                            }
                        }
                    })
                    .expect("spawn node runner"),
            );
        }
        drop(reduce_tx);

        // Forwarder: broadcast query jobs to every node runner; route
        // insert jobs to exactly their target shard.
        threads.push(
            std::thread::Builder::new()
                .name("forwarder".into())
                .spawn(move || {
                    while let Ok(job) = fwd_rx.recv() {
                        match &job {
                            Job::Insert { target, .. } => {
                                if node_tx[*target].send(job.clone()).is_err() {
                                    return;
                                }
                            }
                            _ => {
                                for tx in &node_tx {
                                    if tx.send(job.clone()).is_err() {
                                        return;
                                    }
                                }
                            }
                        }
                    }
                })
                .expect("spawn forwarder"),
        );

        // Reducer: fold ν node replies per qid into the global K-NN.
        let k_red = k;
        threads.push(
            std::thread::Builder::new()
                .name("reducer".into())
                .spawn(move || {
                    let mut pending: HashMap<u64, ReduceAcc> = HashMap::new();
                    while let Ok((qid, node_id, reply, _dt)) = reduce_rx.recv() {
                        let acc = pending.entry(qid).or_insert_with(|| ReduceAcc {
                            topk: TopK::new(k_red),
                            per_node: Vec::new(),
                            received: 0,
                            partial: false,
                            shed_nodes: 0,
                        });
                        for &n in &reply.neighbors {
                            acc.topk.push_unique(n);
                        }
                        // A merge of partial per-node answers is itself
                        // partial: the flag must survive reduction so the
                        // caller learns recall was traded for the deadline.
                        acc.partial |= reply.partial;
                        acc.shed_nodes += reply.shed as u32;
                        acc.per_node.push((node_id, reply.comparisons));
                        acc.received += 1;
                        if acc.received == nu {
                            let mut acc = pending.remove(&qid).unwrap();
                            // Deterministic per-node order regardless of
                            // reply arrival order.
                            acc.per_node.sort_by_key(|(id, _)| *id);
                            let out = ReducedQuery {
                                qid,
                                neighbors: acc.topk.into_sorted(),
                                per_node: acc.per_node.into_iter().map(|(_, c)| c).collect(),
                                partial: acc.partial,
                                shed_nodes: acc.shed_nodes,
                            };
                            if done_tx.send(out).is_err() {
                                return;
                            }
                        }
                    }
                })
                .expect("spawn reducer"),
        );

        // Routed-insert line into the forwarder (the Root never sees
        // inserts — they don't consume qids or reducer slots).
        let ingest_tx = fwd_tx.clone();

        // Root: sequence queries, join reduction results with callers.
        threads.push(
            std::thread::Builder::new()
                .name("root".into())
                .spawn(move || {
                    let finish = |red: ReducedQuery, vote: &VoteConfig, latency_s: f64| {
                        let share = positive_share(&red.neighbors, vote);
                        let max_comparisons = red
                            .per_node
                            .iter()
                            .flat_map(|v| v.iter().copied())
                            .max()
                            .unwrap_or(0);
                        QueryResult {
                            qid: red.qid,
                            neighbors: red.neighbors,
                            positive_share: share,
                            prediction: share >= vote.threshold as f64,
                            max_comparisons,
                            per_node_comparisons: red.per_node,
                            latency_s,
                            partial: red.partial,
                            shed_nodes: red.shed_nodes,
                        }
                    };
                    let mut qid = 0u64;
                    while let Ok(req) = root_rx.recv() {
                        match req {
                            RootRequest::Single(q, reply_to) => {
                                let t0 = std::time::Instant::now();
                                if fwd_tx.send(Job::Single { qid, q: Arc::new(q) }).is_err() {
                                    return;
                                }
                                // ICU latency model: one query in flight.
                                let Ok(red) = done_rx.recv() else { return };
                                debug_assert_eq!(red.qid, qid);
                                let result =
                                    finish(red, &vote, t0.elapsed().as_secs_f64());
                                let _ = reply_to.send(result);
                                qid += 1;
                            }
                            RootRequest::Batch { qs, nq, budget, class, reply_to } => {
                                let n = nq;
                                if n == 0 {
                                    let _ = reply_to.send(Vec::new());
                                    continue;
                                }
                                let t0 = std::time::Instant::now();
                                if fwd_tx
                                    .send(Job::Batch {
                                        qid0: qid,
                                        qs: Arc::new(qs),
                                        nq,
                                        budget,
                                        class,
                                    })
                                    .is_err()
                                {
                                    return;
                                }
                                // Per-qid completion is monotone: every
                                // node replies to qid i before i + 1, so
                                // the reducer finishes them in order.
                                let mut results = Vec::with_capacity(n);
                                for i in 0..n {
                                    let Ok(red) = done_rx.recv() else { return };
                                    debug_assert_eq!(red.qid, qid + i as u64);
                                    results.push(finish(
                                        red,
                                        &vote,
                                        t0.elapsed().as_secs_f64(),
                                    ));
                                }
                                qid += n as u64;
                                let _ = reply_to.send(results);
                            }
                        }
                    }
                })
                .expect("spawn root"),
        );

        Orchestrator {
            root_tx,
            ingest_tx,
            admission: None,
            threads,
            node_infos,
            k,
            nu,
            next_ingest: AtomicUsize::new(0),
            ingest: Arc::new(IngestCounters::new()),
        }
    }

    /// Resolve one query through the full Root → Forwarder → nodes →
    /// Reducer → Root pipeline.
    pub fn query(&self, q: &[f32]) -> QueryResult {
        let (tx, rx) = channel();
        self.root_tx.send(RootRequest::Single(q.to_vec(), tx)).expect("root thread gone");
        rx.recv().expect("root dropped reply")
    }

    /// Resolve a block of queries in one admission: the whole block is
    /// flattened once and broadcast to every node, nodes resolve it on
    /// their batched core path, and the Reducer folds replies per query.
    /// Results (neighbors, prediction, comparison counts) are identical
    /// to calling [`query`] per element; `latency_s` of result `i` is
    /// the wall-clock from batch admission to that query's reduction.
    ///
    /// [`query`]: Orchestrator::query
    pub fn query_batch(&self, qs: &[&[f32]]) -> Vec<QueryResult> {
        let nq = qs.len();
        if nq == 0 {
            return Vec::new();
        }
        let dim = qs[0].len();
        let mut flat = Vec::with_capacity(nq * dim);
        for q in qs {
            // Hard check: a ragged batch flattened as-if-rectangular would
            // silently scan byte-shifted garbage for every later query.
            assert_eq!(q.len(), dim, "ragged query batch");
            flat.extend_from_slice(q);
        }
        // Caller-formed bulk blocks are analytics by nature: no latency
        // budget, throughput-oriented.
        self.query_batch_flat(flat, nq, Budget::none(), Class::Analytics)
    }

    /// Flat-buffer variant of [`query_batch`]: the block is already
    /// row-major `nq × dim` (the admission cutter's native shape),
    /// `budget` carries the cut's remaining latency budget plus
    /// enforcement policy to the nodes ([`Budget::none`] when there is no
    /// deadline), and `class` the cut's scheduling class for node-side
    /// overrun attribution.
    ///
    /// [`query_batch`]: Orchestrator::query_batch
    pub fn query_batch_flat(
        &self,
        qs: Vec<f32>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Vec<QueryResult> {
        if nq == 0 {
            return Vec::new();
        }
        assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
        let (tx, rx) = channel();
        self.root_tx
            .send(RootRequest::Batch { qs, nq, budget, class, reply_to: tx })
            .expect("root thread gone");
        rx.recv().expect("root dropped reply")
    }

    /// Append a batch of labeled points to the live cluster (`points`
    /// row-major `labels.len() × dim`), ingest attributed to
    /// [`Class::Monitor`] — live bedside streams are the default
    /// ingester. See [`insert_batch_class`].
    ///
    /// [`insert_batch_class`]: Orchestrator::insert_batch_class
    pub fn insert_batch(&self, points: &[f32], labels: &[bool]) -> InsertOutcome {
        self.insert_batch_class(points, labels, Class::Monitor)
    }

    /// Append a batch of labeled points, attributing the ingest to an
    /// explicit scheduling class (monitor streams vs analytics
    /// backfills — the per-lane `inserted` counter in
    /// [`LaneStats`](crate::coordinator::admission::LaneStats) when the
    /// admission layer is installed).
    ///
    /// Routing: batches go to ONE node each, round-robin — unlike
    /// queries, which broadcast; a point lives on exactly one shard.
    /// Inserts travel Forwarder → node runner directly (no Root
    /// sequencing, no qids), so a sustained ingest stream interleaves
    /// with queries instead of serializing behind them; per node, the
    /// runner's inbox orders inserts against query jobs, so a query
    /// submitted after this call returns observes the points. Requires
    /// live nodes
    /// ([`build_live_cluster`](crate::coordinator::cluster::build_live_cluster));
    /// batch-built nodes panic their runner rather than drop data.
    pub fn insert_batch_class(
        &self,
        points: &[f32],
        labels: &[bool],
        class: Class,
    ) -> InsertOutcome {
        let n = labels.len();
        assert!(n > 0, "empty insert batch");
        assert_eq!(points.len() % n, 0, "insert block not n × dim");
        let target = self.next_ingest.fetch_add(1, Ordering::Relaxed) % self.nu;
        let (tx, rx) = channel();
        self.ingest_tx
            .send(Job::Insert {
                target,
                points: Arc::new(points.to_vec()),
                labels: Arc::new(labels.to_vec()),
                reply: tx,
            })
            .expect("forwarder gone");
        let r = rx.recv().expect("node dropped insert reply");
        self.ingest.record_batch(r.accepted);
        self.ingest.record_seals(r.sealed_now);
        if let Some(q) = &self.admission {
            q.note_ingest(class, r.accepted);
        }
        InsertOutcome {
            node: target,
            accepted: r.accepted,
            node_total: r.total,
            sealed_now: r.sealed_now,
            sealed_total: r.sealed_total,
        }
    }

    /// Cluster-wide ingest telemetry snapshot.
    pub fn ingest_stats(&self) -> IngestStats {
        self.ingest.snapshot()
    }

    /// Install the deadline-aware admission layer (see
    /// [`crate::coordinator::admission`]): independent callers
    /// [`submit`](Orchestrator::submit) single queries with latency
    /// budgets and a cutter thread coalesces them into
    /// [`query_batch`](Orchestrator::query_batch)-shaped blocks, cutting
    /// on fill or on the earliest deadline. Replaces (and drains) any
    /// previously installed queue.
    pub fn enable_admission(&mut self, cfg: AdmissionConfig) {
        // Drain the old queue before the new one starts competing for
        // the root channel.
        self.admission = None;
        let dispatch = root_dispatcher(self.root_tx.clone());
        self.admission = Some(AdmissionQueue::start(cfg, dispatch));
    }

    /// Admit one [`Class::Monitor`] query with a latency budget; returns
    /// a [`Ticket`] whose [`wait`](Ticket::wait) yields the same result
    /// [`query`] would (bit-identical reduction — the admission layer
    /// only changes *when* work is dispatched, never what it computes)
    /// — except under an enforcing
    /// [`BudgetPolicy`](crate::coordinator::admission::BudgetPolicy)
    /// (`PartialResults`/`Shed`), where a blown budget yields a
    /// prefix-of-the-full answer with [`QueryResult::partial`] set
    /// instead of a late complete one.
    /// Requires [`enable_admission`](Orchestrator::enable_admission).
    /// Bulk callers should use
    /// [`submit_class`](Orchestrator::submit_class) with
    /// [`Class::Analytics`] so they never delay a monitor past its
    /// budget.
    ///
    /// [`query`]: Orchestrator::query
    pub fn submit(&self, q: &[f32], budget: Duration) -> Result<Ticket, AdmissionError> {
        self.submit_class(q, budget, Class::Monitor)
    }

    /// Admit one query into an explicit scheduling lane (see
    /// [`Class`]); same bit-identical-result contract as
    /// [`submit`](Orchestrator::submit).
    pub fn submit_class(
        &self,
        q: &[f32],
        budget: Duration,
        class: Class,
    ) -> Result<Ticket, AdmissionError> {
        self.admission
            .as_ref()
            .expect("call enable_admission before submit")
            .submit_class(q, budget, class)
    }

    /// The installed admission queue, if any (stats, `try_submit`).
    pub fn admission(&self) -> Option<&AdmissionQueue> {
        self.admission.as_ref()
    }

    pub fn num_nodes(&self) -> usize {
        self.nu
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn node_infos(&self) -> &[NodeInfo] {
        &self.node_infos
    }

    /// Total processors (pν) across the cluster.
    pub fn total_processors(&self) -> usize {
        self.node_infos.iter().map(|i| i.cores).sum()
    }
}

impl Drop for Orchestrator {
    fn drop(&mut self) {
        // The admission cutter holds a root_tx clone, so it must drain
        // and exit FIRST or the root thread would never see EOF.
        self.admission = None;
        // Closing root_tx AND the ingest line cascades: root exits, the
        // forwarder inbox loses its last sender, node runners exit, the
        // reducer sees EOF.
        let (dead_tx, _) = channel();
        let _ = std::mem::replace(&mut self.root_tx, dead_tx);
        let (dead_ingest, _) = channel();
        let _ = std::mem::replace(&mut self.ingest_tx, dead_ingest);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

struct ReduceAcc {
    topk: TopK,
    /// `(node_id, per-core comparisons)` — sorted by node id on completion.
    per_node: Vec<(usize, Vec<u64>)>,
    received: usize,
    /// Any node answered partially under budget enforcement.
    partial: bool,
    /// Nodes that shed the batch outright.
    shed_nodes: u32,
}

struct ReducedQuery {
    qid: u64,
    neighbors: Vec<Neighbor>,
    per_node: Vec<Vec<u64>>,
    partial: bool,
    shed_nodes: u32,
}
