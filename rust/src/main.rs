//! DSLSH command-line interface — the system launcher.
//!
//! ```text
//! dslsh gen-data   --dataset ahe-51-5c --n 100000 --queries 250 --out corpus
//! dslsh exp        table1|fig3|fig4|table2|table3 [--full|--smoke] [--engine xla]
//! dslsh query      --dataset <file> --queries <file> [--m 125 --l 120 ...]
//! dslsh serve-node --listen 0.0.0.0:7001
//! dslsh orchestrate --nodes host1:7001,host2:7001 --dataset <file> ...
//! dslsh selfcheck
//! ```

use std::process::ExitCode;

use anyhow::{anyhow, bail, Context, Result};

use dslsh::coordinator::orchestrator::{NodeHandle, Orchestrator};
use dslsh::coordinator::{build_cluster, ClusterConfig, EngineKind};
use dslsh::data::{Dataset, WindowSpec};
use dslsh::experiments::scaling::{self, ScalingOptions, ScalingTable};
use dslsh::experiments::table1::{self, Table1Options};
use dslsh::experiments::tradeoff::{self, TradeoffOptions};
use dslsh::experiments::{cached_corpus, Scale};
use dslsh::knn::predict::VoteConfig;
use dslsh::net::{serve_node, RemoteNode};
use dslsh::slsh::{InnerParams, SlshParams};
use dslsh::util::cli::Args;
use dslsh::util::threadpool::chunk_ranges;

const VALUED: &[&str] = &[
    "dataset", "n", "queries", "seed", "out", "engine", "m", "l", "m-in", "l-in", "alpha", "k",
    "nu", "p", "listen", "nodes", "max-configs", "results",
];

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let args = Args::parse_from(argv.into_iter().skip(1), VALUED);
    let result = match cmd.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "exp" => cmd_exp(&args),
        "query" => cmd_query(&args),
        "serve-node" => cmd_serve_node(&args),
        "orchestrate" => cmd_orchestrate(&args),
        "selfcheck" => cmd_selfcheck(),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "DSLSH — Distributed Stratified LSH for critical event prediction
commands:
  gen-data     generate a synthetic ABP corpus (--dataset ahe-301-30c|ahe-51-5c --n N --queries Q --seed S --out STEM)
  exp          reproduce a paper experiment: table1 | fig3 | fig4 | table2 | table3
               [--full | --smoke] [--n N] [--queries Q] [--seed S] [--engine native|xla]
               [--nu V] [--p P] [--max-configs K] [--results DIR]
  query        one-shot queries (--dataset FILE --queries FILE [--m M --l L --m-in MI --l-in LI --alpha A --k K --nu V --p P --engine E])
  serve-node   run a TCP SLSH node (--listen ADDR)
  orchestrate  drive remote nodes (--nodes A1,A2,... --dataset FILE --queries FILE [--m --l --p ...])
  selfcheck    verify the PJRT runtime + artifacts"
        .to_string()
}

fn dataset_spec(name: &str) -> Result<WindowSpec> {
    match name {
        "ahe-301-30c" => Ok(WindowSpec::ahe_301_30c()),
        "ahe-51-5c" => Ok(WindowSpec::ahe_51_5c()),
        other => bail!("unknown dataset '{other}' (ahe-301-30c | ahe-51-5c)"),
    }
}

fn scale_from(args: &Args) -> Result<Scale> {
    let mut scale = if args.has_flag("full") {
        Scale::full()
    } else if args.has_flag("smoke") {
        Scale::smoke()
    } else {
        Scale::default_scale()
    };
    if let Some(n) = args.get_usize("n")? {
        scale.n_301 = n;
        scale.n_51 = n;
    }
    if let Some(q) = args.get_usize("queries")? {
        scale.queries = q;
    }
    Ok(scale)
}

fn engine_from(args: &Args) -> Result<EngineKind> {
    let name = args.str_or("engine", "native");
    EngineKind::parse(name).ok_or_else(|| anyhow!("unknown engine '{name}' (native|xla)"))
}

fn params_from(args: &Args, data: &Dataset) -> Result<SlshParams> {
    let m = args.usize_or("m", 125)?;
    let l = args.usize_or("l", 120)?;
    let k = args.usize_or("k", 10)?;
    let seed = args.u64_or("seed", 42)?;
    let mut params = dslsh::experiments::outer_params(data, m, l, seed, k);
    if let Some(m_in) = args.get_usize("m-in")? {
        params.inner = Some(InnerParams {
            m: m_in,
            l: args.usize_or("l-in", 20)?,
            alpha: args.f64_or("alpha", 0.005)?,
            seed: seed ^ 0x5157,
        });
    }
    Ok(params)
}

// ---------------------------------------------------------------------------

fn cmd_gen_data(args: &Args) -> Result<()> {
    let spec = dataset_spec(args.str_or("dataset", "ahe-51-5c"))?;
    let n = args.usize_or("n", 100_000)?;
    let q = args.usize_or("queries", 250)?;
    let seed = args.u64_or("seed", 42)?;
    let corpus = cached_corpus(&spec, n, q, seed)?;
    let stats = dslsh::data::dataset::stats(&spec, &corpus.data);
    println!(
        "{}: n={} (%non-AHE {:.2}%), queries={} (%non-AHE {:.2}%)",
        stats.name,
        stats.n,
        stats.pct_negative * 100.0,
        corpus.queries.len(),
        corpus.queries.pct_negative() * 100.0
    );
    if let Some(out) = args.get_str("out") {
        corpus.data.save(std::path::Path::new(&format!("{out}.data")))?;
        corpus.queries.save(std::path::Path::new(&format!("{out}.queries")))?;
        println!("wrote {out}.data and {out}.queries");
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow!("exp needs a target: table1|fig3|fig4|table2|table3"))?;
    let scale = scale_from(args)?;
    let seed = args.u64_or("seed", 42)?;
    let engine = engine_from(args)?;
    let results_dir = std::path::PathBuf::from(args.str_or("results", "results"));

    let table = match which {
        "table1" => table1::run(&Table1Options { scale, seed })?,
        "fig3" | "fig4" => {
            let mut opts = TradeoffOptions::paper_defaults(scale, seed);
            opts.engine = engine;
            opts.nu = args.usize_or("nu", opts.nu)?;
            opts.p = args.usize_or("p", opts.p)?;
            opts.max_configs = args.get_usize("max-configs")?;
            let r = if which == "fig3" {
                tradeoff::run_fig3(&opts)?
            } else {
                tradeoff::run_fig4(&opts)?
            };
            println!("{}", r.scatter);
            println!(
                "PKNN reference: {} comparisons/processor, MCC = {:.3}",
                r.pknn_comps, r.pknn_mcc
            );
            r.table
        }
        "table2" | "table3" => {
            let which =
                if which == "table2" { ScalingTable::Table2 } else { ScalingTable::Table3 };
            let mut opts = ScalingOptions::for_table(which, scale, seed);
            opts.engine = engine;
            opts.p = args.usize_or("p", opts.p)?;
            opts.m = args.usize_or("m", opts.m)?;
            opts.l = args.usize_or("l", opts.l)?;
            if let Some(nus) = args.usize_list("nu")? {
                opts.nus = nus;
            }
            let r = scaling::run(which, &opts)?;
            println!("PKNN MCC = {:.3} (topology-independent)", r.pknn_mcc);
            r.table
        }
        other => bail!("unknown experiment '{other}'"),
    };
    println!("{}", table.render());
    table.save(&results_dir, which)?;
    println!("saved {}/{which}.csv and .json", results_dir.display());
    Ok(())
}

fn cmd_query(args: &Args) -> Result<()> {
    let data = Dataset::load(std::path::Path::new(args.require_str("dataset")?))
        .context("loading dataset")?;
    let queries = Dataset::load(std::path::Path::new(args.require_str("queries")?))
        .context("loading queries")?;
    let params = params_from(args, &data)?;
    let cfg = ClusterConfig::new(args.usize_or("nu", 2)?, args.usize_or("p", 4)?)
        .with_engine(engine_from(args)?);
    let cluster = build_cluster(&data, &params, &cfg)?;
    let mut confusion = dslsh::metrics::Confusion::new();
    for i in 0..queries.len() {
        let r = cluster.query(queries.point(i))?;
        confusion.push(r.prediction, queries.labels[i]);
        println!(
            "q{i}: pred={} share={:.3} max_comps={} latency={:.2}ms nn={:?}",
            r.prediction as u8,
            r.positive_share,
            r.max_comparisons,
            r.latency_s * 1e3,
            r.neighbors.iter().take(3).map(|n| n.id).collect::<Vec<_>>()
        );
    }
    println!("MCC = {:.4}  ({:?})", confusion.mcc(), confusion);
    Ok(())
}

fn cmd_serve_node(args: &Args) -> Result<()> {
    let addr = args.str_or("listen", "0.0.0.0:7001");
    let listener = std::net::TcpListener::bind(addr).context("binding listener")?;
    println!("dslsh node listening on {}", listener.local_addr()?);
    loop {
        let served = serve_node(&listener, None)?;
        println!("connection done after {served} queries; awaiting next orchestrator");
    }
}

fn cmd_orchestrate(args: &Args) -> Result<()> {
    let node_addrs: Vec<&str> = args.require_str("nodes")?.split(',').collect();
    let data = Dataset::load(std::path::Path::new(args.require_str("dataset")?))?;
    let queries = Dataset::load(std::path::Path::new(args.require_str("queries")?))?;
    let params = params_from(args, &data)?;
    let p = args.usize_or("p", 8)?;
    let nu = node_addrs.len();
    let mut nodes: Vec<Box<dyn NodeHandle>> = Vec::with_capacity(nu);
    for (node_id, range) in chunk_ranges(data.len(), nu).into_iter().enumerate() {
        let shard = data.shard(range.clone());
        println!("shipping shard {node_id} ({} points) to {}", shard.len(), node_addrs[node_id]);
        nodes.push(Box::new(RemoteNode::connect(
            node_addrs[node_id],
            node_id,
            shard,
            range.start as u64,
            &params,
            p,
        )?));
    }
    let orch = Orchestrator::start(nodes, params.k, VoteConfig::default());
    let mut confusion = dslsh::metrics::Confusion::new();
    let t0 = std::time::Instant::now();
    for i in 0..queries.len() {
        let r = orch.query(queries.point(i))?;
        confusion.push(r.prediction, queries.labels[i]);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{} queries in {:.2}s ({:.1} q/s), MCC = {:.4}",
        queries.len(),
        dt,
        queries.len() as f64 / dt,
        confusion.mcc()
    );
    Ok(())
}

fn cmd_selfcheck() -> Result<()> {
    print!("artifacts: ");
    let manifest = dslsh::runtime::Manifest::discover()?;
    println!("{} kernels at {:?}", manifest.artifacts.len(), manifest.dir);
    print!("pjrt: ");
    let service = dslsh::runtime::XlaService::start()?;
    let engine = service.engine();
    use dslsh::engine::{DistanceEngine, Metric};
    let q = vec![1.0f32; 30];
    let data: Vec<f32> = (0..30 * 4).map(|i| i as f32).collect();
    let labels = vec![false; 4];
    let mut topk = dslsh::knn::TopK::new(2);
    let c = engine.scan(Metric::L1, &q, &data, 30, &[0, 1, 2, 3], &labels, 0, &mut topk);
    anyhow::ensure!(c == 4, "scan count mismatch");
    let best = topk.into_sorted();
    anyhow::ensure!(best[0].id == 0, "unexpected nearest row");
    println!("ok (l1 scan through JAX/Pallas artifact verified)");
    Ok(())
}
