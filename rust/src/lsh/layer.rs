//! One LSH layer: `L` tables indexed by independent composed hashes
//! `g_1..g_L ∈ H' = H^m` (paper §2). A layer can be built over *any*
//! subset of tables — the intra-node parallelization unit: core `P_i`
//! owns tables `{t : t ≡ i (mod p)}`, each built entirely independently
//! ("no overlap in the computations for any pair of hashes").
//!
//! Key computation goes through the families' bit-packed evaluators
//! (`lsh::family`): per-table keys are assembled as `u64` words with
//! shifts/masks rather than per-function scalar walks, with the layout
//! pinned bit-identical to [`PackedKey::from_bits`].

use crate::lsh::family::{ComposedHash, LayerSpec};
use crate::lsh::key::PackedKey;
use crate::lsh::table::{Table, TableBuilder};

/// Read-only view of a point set (row-major dense f32).
pub trait Points: Sync {
    fn dim(&self) -> usize;
    fn len(&self) -> usize;
    fn point(&self, i: usize) -> &[f32];
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Points for crate::data::Dataset {
    fn dim(&self) -> usize {
        self.dim
    }
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn point(&self, i: usize) -> &[f32] {
        crate::data::Dataset::point(self, i)
    }
}

/// A borrowed row-major matrix as a point set (used for bucket
/// sub-populations and test fixtures).
pub struct SliceView<'a> {
    pub data: &'a [f32],
    pub dim: usize,
}

impl<'a> Points for SliceView<'a> {
    fn dim(&self) -> usize {
        self.dim
    }
    fn len(&self) -> usize {
        self.data.len() / self.dim
    }
    fn point(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }
}

/// One built table together with its (global) table index and hash.
pub struct LayerTable {
    /// Global table index `t ∈ [0, L)` — determines the hash instance.
    pub t: usize,
    pub hash: Box<dyn ComposedHash>,
    pub table: Table,
}

/// A set of built LSH tables belonging to one layer (possibly a subset of
/// the layer's `L` tables, when sharded across cores).
pub struct LshLayer {
    pub spec: LayerSpec,
    pub tables: Vec<LayerTable>,
}

impl LshLayer {
    /// Build tables `table_indices` of the layer over `points`, whose ids
    /// are `0..points.len()` (local ids; callers map to global ids).
    pub fn build<P: Points + ?Sized>(spec: &LayerSpec, points: &P, table_indices: &[usize]) -> Self {
        let tables = table_indices
            .iter()
            .map(|&t| {
                let hash = spec.instantiate(t);
                let mut builder = TableBuilder::with_capacity(points.len());
                for i in 0..points.len() {
                    builder.insert(hash.hash(points.point(i)), i as u32);
                }
                LayerTable { t, hash, table: builder.freeze() }
            })
            .collect();
        Self { spec: spec.clone(), tables }
    }

    /// Build all `L` tables.
    pub fn build_full<P: Points + ?Sized>(spec: &LayerSpec, points: &P) -> Self {
        let all: Vec<usize> = (0..spec.l).collect();
        Self::build(spec, points, &all)
    }

    /// Probe every owned table with `q`, invoking `visit` with each
    /// colliding bucket (a slice of local point ids).
    pub fn probe_each<'s>(&'s self, q: &[f32], mut visit: impl FnMut(usize, &'s [u32])) {
        for lt in &self.tables {
            let key = lt.hash.hash(q);
            let ids = lt.table.probe(&key);
            if !ids.is_empty() {
                visit(lt.t, ids);
            }
        }
    }

    /// Hash a block of queries (row-major `nq × dim`) against every owned
    /// table in one pass, filling `keys` with the layout
    /// `keys[table_pos * nq + query]`. `keys` is cleared first and reused
    /// across batches — the batched request path's hashing stage.
    pub fn hash_batch(&self, qs: &[f32], dim: usize, keys: &mut Vec<PackedKey>) {
        keys.clear();
        for lt in &self.tables {
            lt.hash.hash_batch(qs, dim, keys);
        }
    }

    pub fn num_entries(&self) -> usize {
        self.tables.iter().map(|t| t.table.num_entries()).sum()
    }

    pub fn mem_bytes(&self) -> usize {
        self.tables.iter().map(|t| t.table.mem_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::family::LayerSpec;
    use crate::util::rng::Xoshiro256;

    /// Clustered fixture: `clusters` centers with `per` near-copies each.
    fn clustered(clusters: usize, per: usize, dim: usize, seed: u64) -> (Vec<f32>, usize) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = Vec::with_capacity(clusters * per * dim);
        for _ in 0..clusters {
            let center: Vec<f32> =
                (0..dim).map(|_| rng.gen_f64(30.0, 150.0) as f32).collect();
            for _ in 0..per {
                for &c in &center {
                    data.push(c + rng.gen_normal(0.0, 0.4) as f32);
                }
            }
        }
        (data, dim)
    }

    #[test]
    fn build_covers_all_points_in_every_table() {
        let (data, dim) = clustered(10, 20, 30, 1);
        let view = SliceView { data: &data, dim };
        let spec = LayerSpec::outer_l1(dim, 32, 6, 20.0, 180.0, 7);
        let layer = LshLayer::build_full(&spec, &view);
        assert_eq!(layer.tables.len(), 6);
        for lt in &layer.tables {
            assert_eq!(lt.table.num_entries(), view.len(), "table {}", lt.t);
        }
    }

    #[test]
    fn probe_finds_near_duplicates() {
        let (data, dim) = clustered(8, 25, 30, 2);
        let view = SliceView { data: &data, dim };
        let spec = LayerSpec::outer_l1(dim, 24, 12, 20.0, 180.0, 3);
        let layer = LshLayer::build_full(&spec, &view);
        // Query = point 0 itself: must find itself in every table, and
        // mostly its cluster-mates across tables.
        let q = view.point(0).to_vec();
        let mut self_hits = 0;
        let mut mates = std::collections::HashSet::new();
        layer.probe_each(&q, |_t, ids| {
            if ids.contains(&0) {
                self_hits += 1;
            }
            for &id in ids {
                mates.insert(id);
            }
        });
        assert_eq!(self_hits, 12, "a point must collide with itself in all tables");
        let cluster0 = (0..25u32).collect::<std::collections::HashSet<_>>();
        let recall = mates.intersection(&cluster0).count();
        assert!(recall > 12, "recall of own cluster too low: {recall}/25");
    }

    #[test]
    fn sharded_build_equals_full_build() {
        // Union of per-core table subsets ≡ full build (same instances).
        let (data, dim) = clustered(5, 10, 30, 4);
        let view = SliceView { data: &data, dim };
        let spec = LayerSpec::outer_l1(dim, 16, 8, 20.0, 180.0, 9);
        let full = LshLayer::build_full(&spec, &view);
        let p = 3;
        let shards: Vec<LshLayer> = (0..p)
            .map(|core| {
                let mine: Vec<usize> = (0..spec.l).filter(|t| t % p == core).collect();
                LshLayer::build(&spec, &view, &mine)
            })
            .collect();
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..20 {
            let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
            let mut from_full: Vec<(usize, Vec<u32>)> = Vec::new();
            full.probe_each(&q, |t, ids| from_full.push((t, ids.to_vec())));
            let mut from_shards: Vec<(usize, Vec<u32>)> = Vec::new();
            for s in &shards {
                s.probe_each(&q, |t, ids| from_shards.push((t, ids.to_vec())));
            }
            from_full.sort();
            from_shards.sort();
            assert_eq!(from_full, from_shards);
        }
    }

    #[test]
    fn hash_batch_layout_matches_sequential_hashes() {
        // keys[table_pos * nq + qi] must equal hashing query qi with
        // table pos's instance — the layout contract the batched SLSH
        // resolution path relies on.
        let (data, dim) = clustered(8, 20, 30, 7);
        let view = SliceView { data: &data, dim };
        for spec in [
            LayerSpec::outer_l1(dim, 24, 10, 20.0, 180.0, 5),
            LayerSpec::inner_cosine(dim, 20, 6, 8),
        ] {
            let layer = LshLayer::build_full(&spec, &view);
            let mut rng = Xoshiro256::seed_from_u64(6);
            let mut keys = Vec::new();
            for nq in [1usize, 5, 8, 11] {
                let qs: Vec<f32> =
                    (0..nq * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
                layer.hash_batch(&qs, dim, &mut keys);
                assert_eq!(keys.len(), layer.tables.len() * nq);
                for (pos, lt) in layer.tables.iter().enumerate() {
                    for qi in 0..nq {
                        let single = lt.hash.hash(&qs[qi * dim..(qi + 1) * dim]);
                        assert_eq!(keys[pos * nq + qi], single, "pos={pos} qi={qi} nq={nq}");
                    }
                }
            }
        }
    }

    #[test]
    fn cosine_layer_builds_and_probes() {
        let (data, dim) = clustered(6, 15, 30, 6);
        let view = SliceView { data: &data, dim };
        let spec = LayerSpec::inner_cosine(dim, 20, 5, 11);
        let layer = LshLayer::build_full(&spec, &view);
        let q = view.point(3).to_vec();
        let mut found_self = false;
        layer.probe_each(&q, |_t, ids| {
            if ids.contains(&3) {
                found_self = true;
            }
        });
        assert!(found_self);
    }

    #[test]
    fn empty_points_build() {
        let view = SliceView { data: &[], dim: 30 };
        let spec = LayerSpec::outer_l1(30, 8, 4, 0.0, 1.0, 1);
        let layer = LshLayer::build_full(&spec, &view);
        let q = vec![0.5f32; 30];
        let mut called = false;
        layer.probe_each(&q, |_, _| called = true);
        assert!(!called);
    }
}
