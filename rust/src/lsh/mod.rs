//! Locality Sensitive Hashing substrate: hash families, packed keys,
//! bucket tables, and multi-table layers (paper §2).

pub mod family;
pub mod key;
pub mod layer;
pub mod table;

pub use family::{BitSamplingL1, ComposedHash, LayerSpec, Metric, RandomProjection};
pub use key::PackedKey;
pub use layer::{LshLayer, Points, SliceView};
