//! Locality Sensitive Hashing substrate: hash families, packed keys,
//! bucket tables, multi-table layers (paper §2), and multi-probe
//! perturbation sequences.
//!
//! # Probe-sequence math
//!
//! A composed hash `g = (h_1, …, h_m)` buckets a query `q` by `m`
//! threshold decisions. Each bit `i` carries a *margin* `z_i ≥ 0` — how
//! far `q` sits from that bit's decision boundary (`|q[c_i] − t_i|` for
//! L1 bit sampling, `|w_i · q|` for signed random projections). A near
//! neighbor `p` of `q` most plausibly lands in the bucket whose key
//! differs from `g(q)` in the bits with the *smallest* margins, so the
//! probe sequence enumerates perturbation sets `S ⊆ {1..m}`, `|S| ≤ 2`,
//! by ascending total margin `Σ_{i∈S} z_i` (Lv et al.'s shift/expand
//! heap, see [`probe`]). Probing the top `P` buckets per table recovers
//! most of the recall of building extra tables at zero memory and zero
//! network cost — the lever Bahmani et al. (arXiv:1210.7057) use for
//! distributed LSH, and the knob this crate exposes per request via
//! [`ProbeSpec`].

pub mod family;
pub mod key;
pub mod layer;
pub mod probe;
pub mod table;

pub use family::{BitSamplingL1, ComposedHash, LayerSpec, Metric, RandomProjection};
pub use key::PackedKey;
pub use layer::{LshLayer, Points, SliceView};
pub use probe::{max_probe_universe, ProbeGen, ProbeSpec, MAX_PROBES};
