//! Packed hash keys.
//!
//! A composed hash function `g ∈ H' = H^m` maps a point to `m` bits
//! (`m ≤ 256` covers the paper's grids: m_out ≤ 200, m_in ≤ 115). Keys are
//! packed into four `u64` words with a precomputed 64-bit digest so bucket
//! lookup costs one integer compare in the common case and an exact 256-bit
//! compare only on digest collision.

/// Maximum number of bits a composed hash key can carry.
pub const MAX_BITS: usize = 256;

/// A packed ≤256-bit hash key with cached digest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedKey {
    pub words: [u64; 4],
    digest: u64,
}

impl PackedKey {
    /// Build from a bit iterator (LSB-first within words).
    pub fn from_bits<I: IntoIterator<Item = bool>>(bits: I) -> PackedKey {
        let mut words = [0u64; 4];
        let mut count = 0usize;
        for (i, b) in bits.into_iter().enumerate() {
            assert!(i < MAX_BITS, "key exceeds {MAX_BITS} bits");
            if b {
                words[i / 64] |= 1u64 << (i % 64);
            }
            count = i + 1;
        }
        let _ = count;
        PackedKey { words, digest: digest(&words) }
    }

    /// Build from pre-packed words — the packed hash evaluators in
    /// `lsh::family` set bits directly with shifts/masks (bit `i` → word
    /// `i / 64`, position `i % 64`, the same layout [`from_bits`] and
    /// [`KeyBuilder`] use), then seal the key here. The digest is
    /// computed over the words exactly as everywhere else, so keys built
    /// this way are bucket-equal to bit-pushed ones.
    ///
    /// [`from_bits`]: PackedKey::from_bits
    #[inline]
    pub fn from_words(words: [u64; 4]) -> PackedKey {
        PackedKey { words, digest: digest(&words) }
    }

    #[inline]
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Bit at position `i`.
    #[inline]
    pub fn bit(&self, i: usize) -> bool {
        debug_assert!(i < MAX_BITS);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// Hamming distance to another key (used by multi-probe extensions).
    pub fn hamming(&self, other: &PackedKey) -> u32 {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum()
    }

    /// This key with bit `i` flipped — the multi-probe perturbation
    /// primitive. The digest is recomputed, so the returned key is a
    /// first-class bucket key (lookup-equal to hashing a point that
    /// landed one threshold decision away).
    #[inline]
    pub fn toggled(&self, i: usize) -> PackedKey {
        debug_assert!(i < MAX_BITS);
        let mut words = self.words;
        words[i / 64] ^= 1u64 << (i % 64);
        PackedKey { words, digest: digest(&words) }
    }
}

/// Incremental key builder — avoids the iterator overhead of
/// [`PackedKey::from_bits`] when bits arrive one at a time. The hashing
/// hot path in `lsh::family` now packs words directly and seals them
/// with [`PackedKey::from_words`]; the builder remains for incremental
/// callers and as the reference the packed layout is tested against.
#[derive(Debug, Clone)]
pub struct KeyBuilder {
    words: [u64; 4],
    len: usize,
}

impl KeyBuilder {
    #[inline]
    pub fn new() -> Self {
        Self { words: [0; 4], len: 0 }
    }

    #[inline]
    pub fn push(&mut self, bit: bool) {
        debug_assert!(self.len < MAX_BITS);
        if bit {
            self.words[self.len / 64] |= 1u64 << (self.len % 64);
        }
        self.len += 1;
    }

    #[inline]
    pub fn finish(&self) -> PackedKey {
        PackedKey { words: self.words, digest: digest(&self.words) }
    }
}

impl Default for KeyBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// 64-bit digest of the four key words — a round of xxh3-style avalanche
/// mixing per word, then a final finalizer. Fast, and empirically
/// collision-free at the table sizes we build (≤ a few million keys).
#[inline]
pub fn digest(words: &[u64; 4]) -> u64 {
    const P1: u64 = 0x9E37_79B1_85EB_CA87;
    const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    const P3: u64 = 0x1656_67B1_9E37_79F9;
    let mut acc = P3;
    for (i, &w) in words.iter().enumerate() {
        let lane = w.wrapping_mul(P1).rotate_left(31).wrapping_mul(P2);
        acc = (acc ^ lane).rotate_left(27).wrapping_mul(P1).wrapping_add(P2 ^ i as u64);
    }
    // xxh64-style avalanche.
    acc ^= acc >> 33;
    acc = acc.wrapping_mul(P2);
    acc ^= acc >> 29;
    acc = acc.wrapping_mul(P3);
    acc ^ (acc >> 32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn pack_roundtrip_bits() {
        let pattern: Vec<bool> = (0..200).map(|i| (i * 31) % 7 < 3).collect();
        let key = PackedKey::from_bits(pattern.iter().copied());
        for (i, &b) in pattern.iter().enumerate() {
            assert_eq!(key.bit(i), b, "bit {i}");
        }
        // Unset tail stays zero.
        for i in 200..256 {
            assert!(!key.bit(i));
        }
    }

    #[test]
    fn builder_matches_from_bits() {
        let pattern: Vec<bool> = (0..125).map(|i| i % 3 == 0).collect();
        let a = PackedKey::from_bits(pattern.iter().copied());
        let mut kb = KeyBuilder::new();
        for &b in &pattern {
            kb.push(b);
        }
        assert_eq!(kb.finish(), a);
        assert_eq!(kb.finish().digest(), a.digest());
    }

    #[test]
    fn from_words_matches_from_bits() {
        // Packed evaluation writes words directly; the sealed key must be
        // indistinguishable (words + digest) from the bit-pushed one.
        let pattern: Vec<bool> = (0..173).map(|i| (i * 13) % 5 < 2).collect();
        let a = PackedKey::from_bits(pattern.iter().copied());
        let mut words = [0u64; 4];
        for (i, &b) in pattern.iter().enumerate() {
            words[i >> 6] |= u64::from(b) << (i & 63);
        }
        let b = PackedKey::from_words(words);
        assert_eq!(b, a);
        assert_eq!(b.digest(), a.digest());
    }

    #[test]
    fn equality_is_exact_bits() {
        let a = PackedKey::from_bits((0..100).map(|i| i % 2 == 0));
        let mut almost: Vec<bool> = (0..100).map(|i| i % 2 == 0).collect();
        almost[99] = !almost[99];
        let b = PackedKey::from_bits(almost.iter().copied());
        assert_ne!(a, b);
        assert_eq!(a.hamming(&b), 1);
    }

    #[test]
    fn digest_distributes() {
        // Keys differing in one bit must avalanche: ~32 output bits flip.
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut total_flips = 0u32;
        let trials = 500;
        for _ in 0..trials {
            let words = [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()];
            let mut words2 = words;
            let bit = rng.gen_below(256) as usize;
            words2[bit / 64] ^= 1 << (bit % 64);
            total_flips += (digest(&words) ^ digest(&words2)).count_ones();
        }
        let avg = total_flips as f64 / trials as f64;
        assert!((24.0..40.0).contains(&avg), "avalanche avg={avg}");
    }

    #[test]
    fn digest_collision_free_on_structured_keys() {
        // Keys from a dense structured family (worst case for weak hashes).
        let mut seen = std::collections::HashSet::new();
        for i in 0..100_000u64 {
            let key = PackedKey::from_bits((0..64).map(|b| (i >> b) & 1 == 1));
            assert!(seen.insert(key.digest()), "digest collision at {i}");
        }
    }

    #[test]
    fn zero_key_valid() {
        let k = PackedKey::from_bits(std::iter::empty());
        assert_eq!(k.words, [0; 4]);
        assert_eq!(k.hamming(&k), 0);
    }
}
