//! Multi-probe sequences over packed bucket keys.
//!
//! Multi-probe LSH (Lv et al.; Bahmani et al., arXiv:1210.7057) trades a
//! little extra bucket traffic for a lot of recall: instead of adding
//! hash tables, a query visits the buckets whose keys are *small
//! perturbations* of its own key. A bit of a composed hash flips when the
//! point crosses that bit's decision boundary, so the buckets most likely
//! to hold near neighbors are the ones reached by flipping the bits with
//! the smallest *margin* — the distance from the query to the boundary
//! (see [`crate::lsh::family::ComposedHash::margins`]).
//!
//! The generator enumerates perturbation sets of size ≤ 2 (flip-1 and
//! flip-2) in ascending total-margin order with a heap, exactly the
//! shift/expand scheme of Lv et al.:
//!
//! * sort bit positions by margin ascending: `z[0] ≤ z[1] ≤ …`;
//! * seed the heap with `{0}` (in sorted space);
//! * popping `{a}` yields successors `{a+1}` (shift) and `{a, a+1}`
//!   (expand); popping `{a, b}` yields `{a, b+1}` (shift).
//!
//! Every set of size ≤ 2 is generated exactly once, scores are
//! non-decreasing (margins are non-negative, so `f32::to_bits` is a
//! monotone order embedding), and ties break on sorted-space indices —
//! the sequence is a pure function of `(margins, probes)`. Probe 0 is
//! always the unperturbed base key, so `probes = 1` degenerates to the
//! classic single-bucket lookup.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::key::PackedKey;

/// Upper bound accepted for a per-table probe count. Far above any useful
/// setting (the flip-≤2 universe for m ≤ 256 tops out at 32 897 probes);
/// exists so wire/JSON validation can reject garbage.
pub const MAX_PROBES: u32 = 1 << 16;

/// Per-request accuracy/latency knobs that travel with a query all the
/// way down to the per-table bucket walk.
///
/// * `probes` — buckets visited per outer table (flip-0/1/2
///   perturbations, quality-ordered). `1` = today's single-bucket path.
/// * `max_comparisons` — hard cap on candidates scanned per query
///   (per core, per segment on the live path); `0` = unlimited. Enforced
///   deterministically by truncating the candidate list, independent of
///   any clock — unlike the wall-clock [`ScanCancel`] deadline, a capped
///   answer is bit-reproducible.
///
/// [`ScanCancel`]: crate::engine::ScanCancel
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeSpec {
    /// Buckets visited per outer table (≥ 1).
    pub probes: u32,
    /// Candidate-scan budget per query; 0 = unlimited.
    pub max_comparisons: u64,
}

impl ProbeSpec {
    /// The pre-multi-probe behavior: one bucket per table, no cap.
    pub const BASELINE: ProbeSpec = ProbeSpec { probes: 1, max_comparisons: 0 };

    pub fn new(probes: u32, max_comparisons: u64) -> ProbeSpec {
        assert!(probes >= 1, "probes must be >= 1");
        ProbeSpec { probes, max_comparisons }
    }

    /// True when this spec selects exactly the legacy query path.
    #[inline]
    pub fn is_baseline(&self) -> bool {
        *self == Self::BASELINE
    }
}

impl Default for ProbeSpec {
    fn default() -> Self {
        Self::BASELINE
    }
}

/// Number of distinct probes available for an `m`-bit key under the
/// flip-≤2 policy: the base bucket, `m` flip-1s and `m·(m−1)/2` flip-2s.
pub fn max_probe_universe(m: usize) -> usize {
    1 + m + m * (m - 1) / 2
}

/// Heap node: (score_bits, a, b) in *sorted-margin* index space with
/// `b == u32::MAX` marking a singleton set. Lexicographic `Ord` gives the
/// deterministic tie-break.
type SetNode = (u32, u32, u32);

const SINGLE: u32 = u32::MAX;

/// Reusable probe-sequence generator. Holds the sort/heap scratch so the
/// per-(query, table) call allocates nothing in steady state.
#[derive(Debug, Default)]
pub struct ProbeGen {
    order: Vec<u32>,
    heap: BinaryHeap<Reverse<SetNode>>,
}

impl ProbeGen {
    pub fn new() -> ProbeGen {
        ProbeGen { order: Vec::new(), heap: BinaryHeap::new() }
    }

    /// Write the first `probes` keys of the probe sequence for `base`
    /// into `out` (cleared first). `margins[i]` is the non-negative
    /// flip margin of bit `i`; `margins.len()` must equal the key's bit
    /// count. `out[0]` is always `base` itself.
    pub fn generate(
        &mut self,
        base: PackedKey,
        margins: &[f32],
        probes: u32,
        out: &mut Vec<PackedKey>,
    ) {
        out.clear();
        out.push(base);
        if probes <= 1 || margins.is_empty() {
            return;
        }
        let m = margins.len() as u32;
        self.order.clear();
        self.order.extend(0..m);
        let score = |i: u32| margins[i as usize].to_bits();
        self.order.sort_by_key(|&i| (score(i), i));
        self.heap.clear();
        self.heap.push(Reverse((score(self.order[0]), 0, SINGLE)));
        while (out.len() as u32) < probes {
            let Some(Reverse((s, a, b))) = self.heap.pop() else { break };
            let bit_a = self.order[a as usize] as usize;
            let key = if b == SINGLE {
                base.toggled(bit_a)
            } else {
                base.toggled(bit_a).toggled(self.order[b as usize] as usize)
            };
            out.push(key);
            if b == SINGLE {
                if a + 1 < m {
                    let next = score(self.order[(a + 1) as usize]);
                    // Shift: {a} -> {a+1}.
                    self.heap.push(Reverse((next, a + 1, SINGLE)));
                    // Expand: {a} -> {a, a+1}. Margins are non-negative,
                    // so the f32 sum never sorts below either term.
                    let pair = f32::from_bits(s) + f32::from_bits(next);
                    self.heap.push(Reverse((pair.to_bits(), a, a + 1)));
                }
            } else if b + 1 < m {
                // Shift the max element: {a, b} -> {a, b+1}.
                let base_a = score(self.order[a as usize]);
                let next = score(self.order[(b + 1) as usize]);
                let pair = f32::from_bits(base_a) + f32::from_bits(next);
                self.heap.push(Reverse((pair.to_bits(), a, b + 1)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_key(m: usize) -> PackedKey {
        PackedKey::from_bits((0..m).map(|i| i % 3 == 0))
    }

    fn flipped_bits(base: &PackedKey, key: &PackedKey, m: usize) -> Vec<usize> {
        (0..m).filter(|&i| base.bit(i) != key.bit(i)).collect()
    }

    #[test]
    fn probe_zero_is_base_and_probes_one_stops_there() {
        let mut g = ProbeGen::new();
        let base = base_key(16);
        let mut out = Vec::new();
        g.generate(base, &[0.5; 16], 1, &mut out);
        assert_eq!(out, vec![base]);
    }

    #[test]
    fn sequence_is_exact_for_known_margins() {
        // margins: bit2=0.1 < bit0=0.2 < bit1=0.4 — the flip-≤2 order is
        // fully determined: {2}, {0}, {2,0}, {1}, {2,1}, {0,1}.
        let margins = [0.2f32, 0.4, 0.1];
        let base = base_key(3);
        let mut g = ProbeGen::new();
        let mut out = Vec::new();
        g.generate(base, &margins, 16, &mut out);
        let sets: Vec<Vec<usize>> =
            out.iter().map(|k| flipped_bits(&base, k, 3)).collect();
        assert_eq!(
            sets,
            vec![
                vec![],
                vec![2],
                vec![0],
                vec![0, 2],
                vec![1],
                vec![1, 2],
                vec![0, 1],
            ]
        );
        // Universe exhausted exactly.
        assert_eq!(out.len(), max_probe_universe(3));
    }

    #[test]
    fn scores_are_nondecreasing_and_sets_unique() {
        let m = 24;
        let margins: Vec<f32> =
            (0..m).map(|i| ((i * 37) % 17) as f32 * 0.03 + 0.01).collect();
        let base = base_key(m);
        let mut g = ProbeGen::new();
        let mut out = Vec::new();
        g.generate(base, &margins, u32::MAX.min(4096), &mut out);
        assert_eq!(out.len(), max_probe_universe(m));
        let mut seen = std::collections::HashSet::new();
        let mut last = -1.0f32;
        for key in &out {
            let bits = flipped_bits(&base, key, m);
            assert!(bits.len() <= 2);
            assert!(seen.insert(bits.clone()), "duplicate probe set {bits:?}");
            let score: f32 = bits.iter().map(|&i| margins[i]).sum();
            assert!(score >= last - 1e-6, "score regressed: {score} < {last}");
            last = score;
        }
    }

    #[test]
    fn prefix_property_holds() {
        // The P-probe sequence is a strict prefix of the (P+1)-probe one.
        let m = 12;
        let margins: Vec<f32> = (0..m).map(|i| (i as f32 * 0.7).sin().abs()).collect();
        let base = base_key(m);
        let mut g = ProbeGen::new();
        let mut full = Vec::new();
        g.generate(base, &margins, 64, &mut full);
        for p in 1..=16u32 {
            let mut out = Vec::new();
            g.generate(base, &margins, p, &mut out);
            assert_eq!(out[..], full[..out.len()]);
            assert_eq!(out.len(), (p as usize).min(full.len()));
        }
    }

    #[test]
    fn tie_margins_break_on_bit_index() {
        // All-equal margins: order must fall back to bit index, giving
        // {0}, {1}, {0,1}, {2}, {1,2}?... — exact order pinned below.
        let margins = [0.25f32; 4];
        let base = base_key(4);
        let mut g = ProbeGen::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        g.generate(base, &margins, 32, &mut a);
        g.generate(base, &margins, 32, &mut b);
        assert_eq!(a, b, "generation must be deterministic");
        assert_eq!(flipped_bits(&base, &a[1], 4), vec![0]);
        assert_eq!(flipped_bits(&base, &a[2], 4), vec![1]);
    }

    #[test]
    fn probes_beyond_universe_saturate() {
        let margins = [0.1f32, 0.2];
        let base = base_key(2);
        let mut g = ProbeGen::new();
        let mut out = Vec::new();
        g.generate(base, &margins, 1000, &mut out);
        assert_eq!(out.len(), max_probe_universe(2)); // 1 + 2 + 1
    }

    #[test]
    fn spec_baseline_matches_default() {
        assert_eq!(ProbeSpec::default(), ProbeSpec::BASELINE);
        assert!(ProbeSpec::BASELINE.is_baseline());
        assert!(!ProbeSpec::new(2, 0).is_baseline());
        assert!(!ProbeSpec::new(1, 100).is_baseline());
    }
}
