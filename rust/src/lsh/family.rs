//! Locality-sensitive hash families (paper §2).
//!
//! * [`BitSamplingL1`] — the bit-sampling family for the `l1` norm
//!   (Gionis, Indyk & Motwani 1999 [5]). Classically one embeds points
//!   into the Hamming cube via unary coding of quantized coordinates and
//!   samples bits; sampling bit `(j, t)` of the unary code is exactly the
//!   predicate `x_j ≥ t` for a coordinate `j` and a threshold `t` uniform
//!   over the value range — we implement that continuous equivalent
//!   directly. Collision probability of a single bit is
//!   `1 − E_j |x_j − y_j| / (hi − lo)`, monotone decreasing in ‖x−y‖₁.
//!
//! * [`RandomProjection`] — the sign-random-projection family for the
//!   cosine distance (Charikar 2002 [2]): bit = sign(r·x), r ~ N(0, I).
//!   P[h(x) = h(y)] = 1 − θ(x, y)/π.
//!
//! A *composed* function `g ∈ H' = H^m` concatenates `m` independent bits
//! into a [`PackedKey`]. Families are **specified** by `(seed, params)` so
//! the Root can broadcast a compact [`OuterSpec`] and every node
//! reconstructs bit-identical instances — the paper's "the same hash
//! family instances need to be used" requirement without shipping the
//! function tables.
//!
//! Hash evaluation is **bit-packed**: each predicate writes its bit
//! straight into the key's `u64` words with a branch-free shift/mask
//! (`words[i >> 6] |= u64::from(pred) << (i & 63)`) — the gaoya-style
//! simhash packing — instead of walking a per-bit builder. The layout is
//! exactly [`PackedKey::from_bits`]'s (bit `i` → word `i / 64`, position
//! `i % 64`), so packed keys are bucket-identical to bit-pushed ones;
//! [`PackedKey::from_words`] seals words into a digested key.

use crate::lsh::key::{PackedKey, MAX_BITS};
use crate::util::rng::Xoshiro256;

/// Queries hashed per pass of the batched hashers: small enough for the
/// key builders to live in registers/stack, large enough to amortize one
/// walk of the projection/threshold arrays over the whole tile.
pub const HASH_TILE: usize = 8;

/// A composed LSH function: point → m-bit key.
pub trait ComposedHash: Send + Sync {
    /// Number of bits (`m`).
    fn bits(&self) -> usize;
    /// Hash a point.
    fn hash(&self, x: &[f32]) -> PackedKey;

    /// Hash a block of points (row-major `nq × dim`), appending one key
    /// per point to `out`. Keys MUST be identical to calling [`hash`] per
    /// point — the default does exactly that; families override it to
    /// walk their parameter arrays once per tile instead of once per
    /// point.
    ///
    /// [`hash`]: ComposedHash::hash
    fn hash_batch(&self, xs: &[f32], dim: usize, out: &mut Vec<PackedKey>) {
        debug_assert!(dim > 0 && xs.len() % dim == 0);
        for x in xs.chunks_exact(dim) {
            out.push(self.hash(x));
        }
    }

    /// Per-bit flip margins for multi-probe ordering: `out[i]` is a
    /// non-negative score of how far `x` sits from bit `i`'s decision
    /// boundary (smaller = more likely a near neighbor lands across it).
    /// `out` is cleared and filled with exactly [`bits`] entries. The
    /// default knows nothing about the family's geometry and reports all
    /// margins equal, which degrades probe ordering to bit-index order —
    /// still deterministic, just uninformed.
    ///
    /// [`bits`]: ComposedHash::bits
    fn margins(&self, x: &[f32], out: &mut Vec<f32>) {
        let _ = x;
        out.clear();
        out.resize(self.bits(), 0.0);
    }
}

/// Bit-sampling family instance for the l1 norm: `m` (coordinate,
/// threshold) pairs.
#[derive(Debug, Clone)]
pub struct BitSamplingL1 {
    coords: Vec<u16>,
    thresholds: Vec<f32>,
}

impl BitSamplingL1 {
    /// Draw a fresh instance: coords uniform over `[0, dim)`, thresholds
    /// uniform over `[lo, hi)` (the dataset's global value range).
    pub fn sample(dim: usize, m: usize, lo: f32, hi: f32, rng: &mut Xoshiro256) -> Self {
        assert!(m <= MAX_BITS, "m={m} exceeds {MAX_BITS}");
        assert!(dim > 0 && hi > lo, "invalid bit-sampling parameters");
        let mut coords = Vec::with_capacity(m);
        let mut thresholds = Vec::with_capacity(m);
        for _ in 0..m {
            coords.push(rng.gen_below(dim as u64) as u16);
            thresholds.push(rng.gen_f64(lo as f64, hi as f64) as f32);
        }
        Self { coords, thresholds }
    }
}

impl ComposedHash for BitSamplingL1 {
    fn bits(&self) -> usize {
        self.coords.len()
    }

    /// Packed evaluation: each threshold predicate ORs its bit into the
    /// key words branch-free — no per-bit builder state, no branches on
    /// the predicate outcome.
    #[inline]
    fn hash(&self, x: &[f32]) -> PackedKey {
        let mut words = [0u64; 4];
        for (i, (&c, &t)) in self.coords.iter().zip(&self.thresholds).enumerate() {
            words[i >> 6] |= u64::from(x[c as usize] >= t) << (i & 63);
        }
        PackedKey::from_words(words)
    }

    /// Batched: the (coord, threshold) arrays are walked ONCE per tile of
    /// [`HASH_TILE`] queries instead of once per query, so the bit-sampling
    /// parameters stay in cache while every query packs its own key words.
    fn hash_batch(&self, xs: &[f32], dim: usize, out: &mut Vec<PackedKey>) {
        debug_assert!(dim > 0 && xs.len() % dim == 0);
        let nq = xs.len() / dim;
        let mut qi = 0usize;
        while qi < nq {
            let tile = (nq - qi).min(HASH_TILE);
            let mut words = [[0u64; 4]; HASH_TILE];
            for (i, (&c, &t)) in self.coords.iter().zip(&self.thresholds).enumerate() {
                let (w, s) = (i >> 6, i & 63);
                for (ti, kw) in words[..tile].iter_mut().enumerate() {
                    kw[w] |= u64::from(xs[(qi + ti) * dim + c as usize] >= t) << s;
                }
            }
            for kw in &words[..tile] {
                out.push(PackedKey::from_words(*kw));
            }
            qi += tile;
        }
    }

    /// Margin of bit `(c, t)` is the L1 distance to the threshold:
    /// `|x[c] − t|`. A neighbor within `r` of `x` can only flip bits whose
    /// threshold lies inside the radius, so small `|x[c] − t|` = likely
    /// flip.
    fn margins(&self, x: &[f32], out: &mut Vec<f32>) {
        out.clear();
        for (&c, &t) in self.coords.iter().zip(&self.thresholds) {
            out.push((x[c as usize] - t).abs());
        }
    }
}

/// Sign-random-projection family instance for cosine distance: `m`
/// Gaussian directions, row-major `m × dim`.
#[derive(Debug, Clone)]
pub struct RandomProjection {
    dirs: Vec<f32>,
    dim: usize,
    m: usize,
}

impl RandomProjection {
    pub fn sample(dim: usize, m: usize, rng: &mut Xoshiro256) -> Self {
        assert!(m <= MAX_BITS, "m={m} exceeds {MAX_BITS}");
        let dirs = (0..m * dim).map(|_| rng.next_normal() as f32).collect();
        Self { dirs, dim, m }
    }
}

impl ComposedHash for RandomProjection {
    fn bits(&self) -> usize {
        self.m
    }

    /// Packed evaluation: each sign bit is ORed into the key words
    /// branch-free (dot accumulation order unchanged, so keys match the
    /// historical builder path bit for bit).
    #[inline]
    fn hash(&self, x: &[f32]) -> PackedKey {
        debug_assert_eq!(x.len(), self.dim);
        let mut words = [0u64; 4];
        for (i, row) in self.dirs.chunks_exact(self.dim).enumerate() {
            let mut dot = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                dot += a * b;
            }
            words[i >> 6] |= u64::from(dot >= 0.0) << (i & 63);
        }
        PackedKey::from_words(words)
    }

    /// Batched: each Gaussian direction row is loaded once per tile of
    /// [`HASH_TILE`] queries (an `m × dim` matrix re-walked per query is
    /// the hashing cost driver at m ≥ 100). Dot products use the same
    /// accumulation order as [`hash`], so keys are identical.
    ///
    /// [`hash`]: ComposedHash::hash
    fn hash_batch(&self, xs: &[f32], dim: usize, out: &mut Vec<PackedKey>) {
        debug_assert_eq!(dim, self.dim);
        debug_assert!(dim > 0 && xs.len() % dim == 0);
        let nq = xs.len() / dim;
        let mut qi = 0usize;
        while qi < nq {
            let tile = (nq - qi).min(HASH_TILE);
            let mut words = [[0u64; 4]; HASH_TILE];
            for (i, row) in self.dirs.chunks_exact(self.dim).enumerate() {
                let (w, s) = (i >> 6, i & 63);
                for (ti, kw) in words[..tile].iter_mut().enumerate() {
                    let x = &xs[(qi + ti) * dim..(qi + ti) * dim + dim];
                    let mut dot = 0.0f32;
                    for (a, b) in row.iter().zip(x) {
                        dot += a * b;
                    }
                    kw[w] |= u64::from(dot >= 0.0) << s;
                }
            }
            for kw in &words[..tile] {
                out.push(PackedKey::from_words(*kw));
            }
            qi += tile;
        }
    }

    /// Margin of a sign bit is the unnormalized distance to the
    /// hyperplane: `|w_i · x|`. Accumulation order matches [`hash`], so
    /// `margins[i] == 0 ⇔` the hash put `x` exactly on the boundary.
    ///
    /// [`hash`]: ComposedHash::hash
    fn margins(&self, x: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.dim);
        out.clear();
        for row in self.dirs.chunks_exact(self.dim) {
            let mut dot = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                dot += a * b;
            }
            out.push(dot.abs());
        }
    }
}

/// Which family a layer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// l1 norm with bit sampling (outer layer).
    L1,
    /// Cosine distance with random projections (inner layer).
    Cosine,
}

impl Metric {
    pub fn tag(self) -> u8 {
        match self {
            Metric::L1 => 0,
            Metric::Cosine => 1,
        }
    }

    pub fn from_tag(t: u8) -> Option<Metric> {
        match t {
            0 => Some(Metric::L1),
            1 => Some(Metric::Cosine),
            _ => None,
        }
    }
}

/// Compact, broadcastable specification of one LSH layer's family draws.
/// Instance for table `t` is reconstructed as
/// `sample(dim, m, …, &mut Xoshiro256::seed_from_u64(seed).fork(t))` —
/// bit-identical on every node.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    pub metric: Metric,
    pub dim: usize,
    pub m: usize,
    pub l: usize,
    /// Value range for bit-sampling thresholds (ignored for Cosine).
    pub lo: f32,
    pub hi: f32,
    pub seed: u64,
}

impl LayerSpec {
    pub fn outer_l1(dim: usize, m: usize, l: usize, lo: f32, hi: f32, seed: u64) -> Self {
        Self { metric: Metric::L1, dim, m, l, lo, hi, seed }
    }

    pub fn inner_cosine(dim: usize, m: usize, l: usize, seed: u64) -> Self {
        Self { metric: Metric::Cosine, dim, m, l, lo: 0.0, hi: 1.0, seed }
    }

    /// Materialize the composed hash for table index `t ∈ [0, l)`.
    pub fn instantiate(&self, t: usize) -> Box<dyn ComposedHash> {
        assert!(t < self.l, "table index {t} out of range (l={})", self.l);
        let mut rng = Xoshiro256::seed_from_u64(self.seed).fork(t as u64);
        match self.metric {
            Metric::L1 => {
                Box::new(BitSamplingL1::sample(self.dim, self.m, self.lo, self.hi, &mut rng))
            }
            Metric::Cosine => Box::new(RandomProjection::sample(self.dim, self.m, &mut rng)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_point(rng: &mut Xoshiro256, dim: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..dim).map(|_| rng.gen_f64(lo as f64, hi as f64) as f32).collect()
    }

    #[test]
    fn identical_points_always_collide() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let x = rand_point(&mut rng, 30, 40.0, 140.0);
        let bs = BitSamplingL1::sample(30, 125, 40.0, 140.0, &mut rng);
        let rp = RandomProjection::sample(30, 64, &mut rng);
        assert_eq!(bs.hash(&x), bs.hash(&x));
        assert_eq!(rp.hash(&x), rp.hash(&x));
    }

    #[test]
    fn bit_sampling_single_bit_collision_matches_theory() {
        // For one bit, P[h(x)=h(y)] = 1 - |x_j - y_j|/(hi-lo) in expectation
        // over (j, t). Check empirically for a fixed pair.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let dim = 30;
        let (lo, hi) = (0.0f32, 100.0f32);
        let x = vec![50.0f32; dim];
        let mut y = x.clone();
        for v in y.iter_mut().take(10) {
            *v += 20.0; // ‖x−y‖₁ = 200 ⇒ expected collision 1 − 200/(30·100) = 0.9333
        }
        let trials = 40_000;
        let mut coll = 0;
        for _ in 0..trials {
            let h = BitSamplingL1::sample(dim, 1, lo, hi, &mut rng);
            if h.hash(&x) == h.hash(&y) {
                coll += 1;
            }
        }
        let p = coll as f64 / trials as f64;
        assert!((p - 0.9333).abs() < 0.01, "p={p}");
    }

    #[test]
    fn bit_sampling_is_monotone_in_l1_distance() {
        // Closer pairs must collide (on full m-bit keys) at least as often.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let dim = 30;
        let x = vec![80.0f32; dim];
        let mut near = x.clone();
        let mut far = x.clone();
        for i in 0..dim {
            near[i] += 1.0;
            far[i] += 8.0;
        }
        let (mut c_near, mut c_far) = (0, 0);
        for _ in 0..3000 {
            let h = BitSamplingL1::sample(dim, 16, 20.0, 180.0, &mut rng);
            if h.hash(&x) == h.hash(&near) {
                c_near += 1;
            }
            if h.hash(&x) == h.hash(&far) {
                c_far += 1;
            }
        }
        assert!(c_near > c_far * 2, "near={c_near} far={c_far}");
    }

    #[test]
    fn random_projection_collision_matches_angle() {
        // P[bit match] = 1 − θ/π. Take orthogonal-ish vectors: θ = π/2 ⇒ 0.5.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let x = {
            let mut v = vec![0.0f32; 30];
            v[0] = 1.0;
            v
        };
        let y = {
            let mut v = vec![0.0f32; 30];
            v[1] = 1.0;
            v
        };
        let trials = 40_000;
        let mut coll = 0;
        for _ in 0..trials {
            let h = RandomProjection::sample(30, 1, &mut rng);
            if h.hash(&x) == h.hash(&y) {
                coll += 1;
            }
        }
        let p = coll as f64 / trials as f64;
        assert!((p - 0.5).abs() < 0.02, "p={p}");
    }

    #[test]
    fn random_projection_scale_invariant() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let x = rand_point(&mut rng, 30, -1.0, 1.0);
        let x2: Vec<f32> = x.iter().map(|v| v * 7.5).collect();
        let h = RandomProjection::sample(30, 100, &mut rng);
        assert_eq!(h.hash(&x), h.hash(&x2), "cosine hashes must ignore scale");
    }

    #[test]
    fn hash_batch_equals_per_point_hash() {
        // Both families, batch sizes around the tile width (1, exact
        // multiples, and stragglers) — keys must match exactly.
        let mut rng = Xoshiro256::seed_from_u64(21);
        let dim = 30;
        let bs = BitSamplingL1::sample(dim, 125, 20.0, 180.0, &mut rng);
        let rp = RandomProjection::sample(dim, 65, &mut rng);
        for nq in [1usize, 3, 8, 9, 16, 23] {
            let xs: Vec<f32> =
                (0..nq * dim).map(|_| rng.gen_f64(20.0, 180.0) as f32).collect();
            for hash in [&bs as &dyn ComposedHash, &rp as &dyn ComposedHash] {
                let mut batched = Vec::new();
                hash.hash_batch(&xs, dim, &mut batched);
                assert_eq!(batched.len(), nq);
                for (qi, key) in batched.iter().enumerate() {
                    let single = hash.hash(&xs[qi * dim..(qi + 1) * dim]);
                    assert_eq!(*key, single, "nq={nq} qi={qi}");
                    assert_eq!(key.digest(), single.digest());
                }
            }
        }
    }

    #[test]
    fn packed_hash_equals_bitwise_reference() {
        // The branch-free packed evaluators must produce exactly the key
        // PackedKey::from_bits builds from the per-bit predicates — same
        // words, same digest — for bit counts in every word-boundary
        // class (< 64, = 64, straddling, > 192).
        let mut rng = Xoshiro256::seed_from_u64(41);
        let dim = 30;
        for m in [1usize, 63, 64, 65, 125, 200] {
            let bs = BitSamplingL1::sample(dim, m, 20.0, 180.0, &mut rng);
            let rp = RandomProjection::sample(dim, m, &mut rng);
            for _ in 0..20 {
                let x = rand_point(&mut rng, dim, 20.0, 180.0);
                let bs_ref = PackedKey::from_bits(
                    bs.coords
                        .iter()
                        .zip(&bs.thresholds)
                        .map(|(&c, &t)| x[c as usize] >= t),
                );
                assert_eq!(bs.hash(&x), bs_ref, "bit-sampling m={m}");
                let rp_ref = PackedKey::from_bits(rp.dirs.chunks_exact(dim).map(|row| {
                    let mut dot = 0.0f32;
                    for (a, b) in row.iter().zip(&x) {
                        dot += a * b;
                    }
                    dot >= 0.0
                }));
                assert_eq!(rp.hash(&x), rp_ref, "random-projection m={m}");
                assert_eq!(rp.hash(&x).digest(), rp_ref.digest());
            }
        }
    }

    #[test]
    fn layer_spec_reconstructs_identical_instances() {
        // Two "nodes" instantiate from the same spec: identical hashes.
        let spec = LayerSpec::outer_l1(30, 125, 8, 20.0, 180.0, 99);
        let mut rng = Xoshiro256::seed_from_u64(6);
        let x = rand_point(&mut rng, 30, 20.0, 180.0);
        for t in 0..spec.l {
            let node_a = spec.instantiate(t);
            let node_b = spec.instantiate(t);
            assert_eq!(node_a.hash(&x), node_b.hash(&x), "table {t}");
        }
        // Different tables give different functions.
        let h0 = spec.instantiate(0);
        let h1 = spec.instantiate(1);
        let diff = (0..50)
            .filter(|_| {
                let p = rand_point(&mut rng, 30, 20.0, 180.0);
                h0.hash(&p) != h1.hash(&p)
            })
            .count();
        assert!(diff > 40, "tables insufficiently independent: {diff}/50");
    }

    #[test]
    fn margins_are_nonnegative_and_agree_with_flip_geometry() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        let dim = 30;
        let bs = BitSamplingL1::sample(dim, 64, 20.0, 180.0, &mut rng);
        let rp = RandomProjection::sample(dim, 48, &mut rng);
        let x = rand_point(&mut rng, dim, 20.0, 180.0);
        let mut mg = Vec::new();
        for hash in [&bs as &dyn ComposedHash, &rp as &dyn ComposedHash] {
            hash.margins(&x, &mut mg);
            assert_eq!(mg.len(), hash.bits());
            assert!(mg.iter().all(|&z| z >= 0.0));
        }
        // Bit-sampling margin is exact: nudging the point by less than the
        // margin on every coordinate cannot flip the bit.
        bs.margins(&x, &mut mg);
        let base = bs.hash(&x);
        let eps = mg.iter().cloned().fold(f32::INFINITY, f32::min) * 0.5;
        if eps.is_finite() && eps > 0.0 {
            let nudged: Vec<f32> = x.iter().map(|v| v + eps.min(1e-3)).collect();
            // Only bits whose margin is below the nudge may flip.
            let after = bs.hash(&nudged);
            for i in 0..bs.bits() {
                if base.bit(i) != after.bit(i) {
                    assert!(mg[i] <= eps.min(1e-3) + 1e-6, "bit {i} flipped past its margin");
                }
            }
        }
        // Default impl: uniform margins of the right arity.
        struct Opaque;
        impl ComposedHash for Opaque {
            fn bits(&self) -> usize {
                7
            }
            fn hash(&self, _x: &[f32]) -> PackedKey {
                PackedKey::from_bits(std::iter::empty())
            }
        }
        Opaque.margins(&x, &mut mg);
        assert_eq!(mg, vec![0.0; 7]);
    }

    #[test]
    fn key_bit_count_matches_m() {
        let spec = LayerSpec::inner_cosine(30, 65, 4, 42);
        let h = spec.instantiate(2);
        assert_eq!(h.bits(), 65);
        let spec2 = LayerSpec::outer_l1(30, 200, 4, 0.0, 1.0, 42);
        assert_eq!(spec2.instantiate(0).bits(), 200);
    }
}
