//! Bucketed hash table for LSH — built from scratch (no `hashbrown`
//! offline, and `std::HashMap<PackedKey, Vec<u32>>` wastes an allocation
//! per bucket).
//!
//! Two-phase design tuned for the LSH access pattern:
//!
//! 1. **Build**: insert `(key, id)` pairs (ids are local point indices);
//!    open-addressing slots store `(digest, key, head)` with bucket
//!    membership as an intrusive linked list threaded through a single
//!    `next[]` array — zero per-bucket allocations.
//! 2. **Freeze**: rewrite membership into a CSR layout (`bucket_off` /
//!    `bucket_ids`) so probing returns a contiguous `&[u32]` slice — the
//!    layout the scan kernels and the XLA engine want.

use crate::lsh::key::PackedKey;

const EMPTY: u32 = u32::MAX;

/// Mutable build-phase table.
pub struct TableBuilder {
    /// Open-addressing slot → bucket index + key (for exact match).
    slot_key: Vec<Option<PackedKey>>,
    slot_bucket: Vec<u32>,
    mask: usize,
    /// Per-inserted-id linked list: next[i] = previous id in same bucket.
    next: Vec<u32>,
    ids: Vec<u32>,
    /// Per-bucket list head (index into `ids`/`next`) and size.
    head: Vec<u32>,
    size: Vec<u32>,
}

impl TableBuilder {
    /// `expected` = number of inserts (the shard size); capacity is the
    /// next power of two ≥ 2 × expected for a ≤0.5 load factor.
    pub fn with_capacity(expected: usize) -> Self {
        let cap = (expected.max(8) * 2).next_power_of_two();
        Self {
            slot_key: vec![None; cap],
            slot_bucket: vec![EMPTY; cap],
            mask: cap - 1,
            next: Vec::with_capacity(expected),
            ids: Vec::with_capacity(expected),
            head: Vec::new(),
            size: Vec::new(),
        }
    }

    /// Insert a point id under its key.
    pub fn insert(&mut self, key: PackedKey, id: u32) {
        let mut slot = (key.digest() as usize) & self.mask;
        loop {
            match &self.slot_key[slot] {
                None => {
                    // New bucket.
                    let b = self.head.len() as u32;
                    self.slot_key[slot] = Some(key);
                    self.slot_bucket[slot] = b;
                    let entry = self.insert_entry(id, EMPTY);
                    self.head.push(entry);
                    self.size.push(1);
                    return;
                }
                Some(k) if *k == key => {
                    let b = self.slot_bucket[slot] as usize;
                    let entry = self.insert_entry(id, self.head[b]);
                    self.head[b] = entry;
                    self.size[b] += 1;
                    return;
                }
                Some(_) => {
                    slot = (slot + 1) & self.mask;
                }
            }
        }
    }

    fn insert_entry(&mut self, id: u32, prev_head: u32) -> u32 {
        let idx = self.ids.len() as u32;
        self.ids.push(id);
        self.next.push(prev_head);
        idx
    }

    /// Finalize into an immutable probe-optimized table.
    pub fn freeze(self) -> Table {
        let nbuckets = self.head.len();
        let mut bucket_off = Vec::with_capacity(nbuckets + 1);
        let mut bucket_ids = Vec::with_capacity(self.ids.len());
        bucket_off.push(0u32);
        for b in 0..nbuckets {
            let mut cur = self.head[b];
            let start = bucket_ids.len();
            while cur != EMPTY {
                bucket_ids.push(self.ids[cur as usize]);
                cur = self.next[cur as usize];
            }
            // The intrusive list reverses insertion order; restore it so
            // bucket contents are deterministic in id order of insertion.
            bucket_ids[start..].reverse();
            bucket_off.push(bucket_ids.len() as u32);
        }
        Table {
            slot_key: self.slot_key,
            slot_bucket: self.slot_bucket,
            mask: self.mask,
            bucket_off,
            bucket_ids,
        }
    }
}

/// Immutable frozen table: key → contiguous id slice.
pub struct Table {
    slot_key: Vec<Option<PackedKey>>,
    slot_bucket: Vec<u32>,
    mask: usize,
    bucket_off: Vec<u32>,
    bucket_ids: Vec<u32>,
}

impl Table {
    /// Probe: ids colliding with `key`, or empty slice.
    #[inline]
    pub fn probe(&self, key: &PackedKey) -> &[u32] {
        match self.find_bucket(key) {
            Some(b) => self.bucket(b),
            None => &[],
        }
    }

    /// Bucket index for a key, if present.
    #[inline]
    pub fn find_bucket(&self, key: &PackedKey) -> Option<usize> {
        let mut slot = (key.digest() as usize) & self.mask;
        loop {
            match &self.slot_key[slot] {
                None => return None,
                Some(k) if *k == *key => return Some(self.slot_bucket[slot] as usize),
                Some(_) => slot = (slot + 1) & self.mask,
            }
        }
    }

    /// Contents of bucket `b`.
    #[inline]
    pub fn bucket(&self, b: usize) -> &[u32] {
        let lo = self.bucket_off[b] as usize;
        let hi = self.bucket_off[b + 1] as usize;
        &self.bucket_ids[lo..hi]
    }

    pub fn num_buckets(&self) -> usize {
        self.bucket_off.len() - 1
    }

    pub fn num_entries(&self) -> usize {
        self.bucket_ids.len()
    }

    /// Iterate `(bucket_index, ids)` — used to find populous buckets for
    /// the inner SLSH layer.
    pub fn buckets(&self) -> impl Iterator<Item = (usize, &[u32])> {
        (0..self.num_buckets()).map(move |b| (b, self.bucket(b)))
    }

    /// Largest bucket size (diagnostics / occupancy reports).
    pub fn max_bucket(&self) -> usize {
        self.buckets().map(|(_, ids)| ids.len()).max().unwrap_or(0)
    }

    /// Approximate heap footprint in bytes (capacity planning).
    pub fn mem_bytes(&self) -> usize {
        self.slot_key.len() * std::mem::size_of::<Option<PackedKey>>()
            + self.slot_bucket.len() * 4
            + self.bucket_off.len() * 4
            + self.bucket_ids.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use std::collections::BTreeMap;

    fn key_of(v: u64) -> PackedKey {
        PackedKey::from_bits((0..64).map(|b| (v >> b) & 1 == 1))
    }

    #[test]
    fn grouping_matches_btreemap_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let n = 20_000;
        let mut builder = TableBuilder::with_capacity(n);
        let mut reference: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for id in 0..n as u32 {
            let v = rng.gen_below(500); // force heavy bucket collisions
            builder.insert(key_of(v), id);
            reference.entry(v).or_default().push(id);
        }
        let table = builder.freeze();
        assert_eq!(table.num_entries(), n);
        assert_eq!(table.num_buckets(), reference.len());
        for (&v, ids) in &reference {
            let got = table.probe(&key_of(v));
            assert_eq!(got, ids.as_slice(), "bucket for {v}");
        }
    }

    #[test]
    fn missing_key_probes_empty() {
        let mut b = TableBuilder::with_capacity(4);
        b.insert(key_of(1), 0);
        let t = b.freeze();
        assert!(t.probe(&key_of(2)).is_empty());
        assert_eq!(t.probe(&key_of(1)), &[0]);
    }

    #[test]
    fn bucket_order_is_insertion_order() {
        let mut b = TableBuilder::with_capacity(8);
        for id in [5u32, 3, 9, 1] {
            b.insert(key_of(7), id);
        }
        let t = b.freeze();
        assert_eq!(t.probe(&key_of(7)), &[5, 3, 9, 1]);
    }

    #[test]
    fn handles_many_distinct_keys_beyond_initial_estimate() {
        // Estimate is exact-n; distinct keys ≈ n (singleton buckets).
        let n = 5000;
        let mut b = TableBuilder::with_capacity(n);
        for id in 0..n as u32 {
            b.insert(key_of(id as u64 * 2654435761), id);
        }
        let t = b.freeze();
        assert_eq!(t.num_buckets(), n);
        for id in 0..n as u32 {
            assert_eq!(t.probe(&key_of(id as u64 * 2654435761)), &[id]);
        }
    }

    #[test]
    fn buckets_iterator_covers_all_entries() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut b = TableBuilder::with_capacity(1000);
        for id in 0..1000u32 {
            b.insert(key_of(rng.gen_below(37)), id);
        }
        let t = b.freeze();
        let mut seen: Vec<u32> = t.buckets().flat_map(|(_, ids)| ids.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..1000).collect::<Vec<_>>());
        assert!(t.max_bucket() >= 1000 / 37);
    }

    #[test]
    fn empty_table() {
        let t = TableBuilder::with_capacity(0).freeze();
        assert_eq!(t.num_buckets(), 0);
        assert_eq!(t.num_entries(), 0);
        assert!(t.probe(&key_of(0)).is_empty());
    }
}
