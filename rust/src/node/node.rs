//! An SLSH node (paper Figure 2): `p` core-workers over a shared-memory
//! shard, with a Master gather/reduce. In the cloud deployment a node is
//! one VM; here it is a thread group (comparisons — the paper's speed
//! metric — are partitioning-determined, so the simulation reproduces the
//! tables exactly; see DESIGN.md §Substitutions).
//!
//! Nodes come in two shapes sharing every serving path:
//!
//! * **batch-built** ([`LocalNode::spawn`]) — workers freeze a static
//!   shard slice at construction; inserts are rejected.
//! * **live** ([`LocalNode::spawn_live`]) — the node starts EMPTY and
//!   owns a growable [`LiveStore`]; [`LocalNode::insert_batch`] appends
//!   points once to the shared store and fans a `WorkerMsg::Insert` to
//!   every core, which hashes the new rows into its own delta tables and
//!   acks. The store is the single seal authority (size-or-age
//!   [`SealPolicy`] on the node's injected clock), so all cores agree on
//!   segment boundaries deterministically.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::admission::{note_batch_overrun, Budget, BudgetPolicy, Class};
use crate::data::Dataset;
use crate::engine::DistanceEngine;
use crate::knn::heap::{Neighbor, TopK};
use crate::lsh::probe::ProbeSpec;
use crate::node::worker::{owned_tables, run_worker, WorkerMsg, WorkerReplyMsg, WorkerSpec};
use crate::slsh::{LiveStore, SealPolicy, SlshParams};
use crate::util::clock::{Clock, SystemClock};

/// A node's answer to one query — what travels back to the Orchestrator.
#[derive(Debug, Clone)]
pub struct NodeReply {
    pub qid: u64,
    /// The node-local K-NN (already reduced across its cores).
    pub neighbors: Vec<Neighbor>,
    /// Comparisons per core for this query (the paper reports the max
    /// across all processors of all nodes).
    pub comparisons: Vec<u64>,
    /// Inner-layer probes per core (diagnostics).
    pub inner_probes: u64,
    /// Wall time the node spent resolving the batch this reply rode in
    /// (fan-out to last core gathered, on the node's injected clock).
    /// Every reply of one batch shares the batch's value — the node
    /// answers per batch, not per query. Zero on shed replies.
    pub scan_ns: u64,
    /// Outer tables consulted for this query, summed across cores —
    /// under budget enforcement less than the node's table count.
    pub tables: u32,
    /// True when budget enforcement stopped at least one core before it
    /// covered all its tables. `neighbors` is then the union of
    /// *per-core table prefixes* (each core stops on a prefix of its OWN
    /// owned tables; cores progress independently), so every returned
    /// neighbor carries its true distance and appears in the unenforced
    /// candidate walk — but the union is not in general a prefix of the
    /// node's full table order. Always false without enforcement.
    pub partial: bool,
    /// True when the node shed the whole batch before any scan work
    /// (budget already spent on arrival under `BudgetPolicy::Shed`).
    /// Implies `partial`.
    pub shed: bool,
}

/// Construction-time information reported by a node.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub node_id: usize,
    pub shard_len: usize,
    pub cores: usize,
    pub build_ms: f64,
}

/// A live node's answer to one [`LocalNode::insert_batch`] (or seal
/// poll): what travels back to the Orchestrator, and over the wire as an
/// `InsertAck` frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertReply {
    /// Points appended by this call (the store never drops).
    pub accepted: u64,
    /// Total points in the node's store afterwards.
    pub total: u64,
    /// Segments sealed during this call.
    pub sealed_now: u64,
    /// Total sealed segments afterwards.
    pub sealed_total: u64,
}

/// A node's answer to a liveness heartbeat — what travels back to the
/// shard dispatcher, and over the wire as a `HeartbeatAck` frame. Any
/// answer at all proves the node lives; for live (streaming) nodes the
/// payload additionally carries ingest progress, because answering a
/// heartbeat runs the node's age-seal check ([`LocalNode::poll_seal`]) —
/// the heartbeat IS the cluster-level seal poll.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeartbeatReply {
    /// Whether this node carries a live (insertable) index. When false
    /// every count below is zero.
    pub live: bool,
    /// Total points in the node's store.
    pub total: u64,
    /// Segments the heartbeat's seal poll sealed just now (age expiry on
    /// a quiet stream).
    pub sealed_now: u64,
    /// Total sealed segments.
    pub sealed_total: u64,
}

impl HeartbeatReply {
    /// The batch-built node's answer: alive, no live index, no counts.
    pub const fn not_live() -> HeartbeatReply {
        HeartbeatReply { live: false, total: 0, sealed_now: 0, sealed_total: 0 }
    }
}

/// One in-process SLSH node: `p` worker threads + shared shard.
pub struct LocalNode {
    node_id: usize,
    worker_tx: Vec<Sender<WorkerMsg>>,
    reply_rx: Receiver<WorkerReplyMsg>,
    handles: Vec<JoinHandle<()>>,
    k: usize,
    p: usize,
    info: NodeInfo,
    next_qid: u64,
    /// Budget-enforcement time source (shared with every worker); a node
    /// anchors a cut's deadline at batch *arrival* on this clock.
    clock: Arc<dyn Clock>,
    /// Live nodes: the shared growable point store (the seal authority);
    /// `None` on batch-built nodes, which reject inserts.
    store: Option<Arc<LiveStore>>,
    insert_seq: u64,
}

impl LocalNode {
    /// Spawn the node: builds all owned tables in parallel across `p`
    /// worker threads (each core constructs its tables independently).
    ///
    /// `engines` supplies one distance engine per core (native or XLA
    /// handles — they may differ, e.g. for ablation).
    pub fn spawn(
        node_id: usize,
        shard: Arc<Dataset>,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        engines: Vec<Box<dyn DistanceEngine>>,
    ) -> LocalNode {
        LocalNode::spawn_with_clock(
            node_id,
            shard,
            id_base,
            params,
            p,
            engines,
            Arc::new(SystemClock::new()),
        )
    }

    /// [`spawn`](LocalNode::spawn) with an injected [`Clock`] — the
    /// budget-enforcement tests drive nodes with `MockClock`/`TickClock`
    /// so partial-scan decisions are deterministic.
    pub fn spawn_with_clock(
        node_id: usize,
        shard: Arc<Dataset>,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        engines: Vec<Box<dyn DistanceEngine>>,
        clock: Arc<dyn Clock>,
    ) -> LocalNode {
        let shard_len = shard.len();
        LocalNode::spawn_inner(node_id, id_base, params, p, engines, clock, Some(shard), None)
            .with_shard_len(shard_len)
    }

    /// Spawn an EMPTY live node: workers follow a shared growable
    /// [`LiveStore`] instead of freezing a static shard, and the node
    /// accepts [`insert_batch`](LocalNode::insert_batch). `policy` is the
    /// seal trigger (size or age on `clock`); global ids are
    /// `id_base + insertion index` — live clusters stride `id_base` per
    /// node (see [`crate::slsh::live::LIVE_ID_STRIDE`]).
    pub fn spawn_live(
        node_id: usize,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        engines: Vec<Box<dyn DistanceEngine>>,
        clock: Arc<dyn Clock>,
        policy: SealPolicy,
    ) -> LocalNode {
        let store = Arc::new(LiveStore::new(params.outer.dim, policy, Arc::clone(&clock)));
        LocalNode::spawn_inner(node_id, id_base, params, p, engines, clock, None, Some(store))
    }

    /// Shared spawn body: exactly one of `shard` (batch-built) or `store`
    /// (live) is `Some`.
    #[allow(clippy::too_many_arguments)]
    fn spawn_inner(
        node_id: usize,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        mut engines: Vec<Box<dyn DistanceEngine>>,
        clock: Arc<dyn Clock>,
        shard: Option<Arc<Dataset>>,
        store: Option<Arc<LiveStore>>,
    ) -> LocalNode {
        assert_eq!(engines.len(), p, "need one engine per core");
        debug_assert!(shard.is_some() != store.is_some());
        let t0 = std::time::Instant::now();
        let (reply_tx, reply_rx) = channel::<WorkerReplyMsg>();
        let (ready_tx, ready_rx) = channel::<usize>();
        let mut worker_tx = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for core in 0..p {
            let (tx, rx) = channel::<WorkerMsg>();
            worker_tx.push(tx);
            let params_c = params.clone();
            let tables = owned_tables(params.outer.l, p, core);
            let spec = match (&shard, &store) {
                (Some(s), _) => WorkerSpec::Static { shard: Arc::clone(s), tables },
                (None, Some(st)) => WorkerSpec::Live { store: Arc::clone(st), tables },
                (None, None) => unreachable!(),
            };
            let engine = engines.remove(0);
            let clock_c = Arc::clone(&clock);
            let reply_tx_c = reply_tx.clone();
            let ready_c = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("node{node_id}-core{core}"))
                .spawn(move || {
                    run_worker(
                        core, spec, id_base, params_c, engine, clock_c, rx, reply_tx_c, ready_c,
                    )
                })
                .expect("spawning worker");
            handles.push(handle);
        }
        drop(ready_tx);
        // Wait until every core finished building its tables.
        let mut built = 0;
        while built < p {
            ready_rx.recv().expect("worker died during build");
            built += 1;
        }
        let info = NodeInfo {
            node_id,
            shard_len: 0,
            cores: p,
            build_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        LocalNode {
            node_id,
            worker_tx,
            reply_rx,
            handles,
            k: params.k,
            p,
            info,
            next_qid: 0,
            clock,
            store,
            insert_seq: 0,
        }
    }

    fn with_shard_len(mut self, shard_len: usize) -> LocalNode {
        self.info.shard_len = shard_len;
        self
    }

    pub fn info(&self) -> &NodeInfo {
        &self.info
    }

    pub fn node_id(&self) -> usize {
        self.node_id
    }

    /// Whether this node accepts online inserts.
    pub fn is_live(&self) -> bool {
        self.store.is_some()
    }

    /// The live store (the seal authority), if this is a live node.
    pub fn store(&self) -> Option<&Arc<LiveStore>> {
        self.store.as_ref()
    }

    /// Append a batch of labeled points to this live node: ONE append to
    /// the shared store (which decides seals), then an `Insert` fan-out so
    /// every core hashes the new rows into its own tables. Returns after
    /// all `p` cores acked — a query admitted after this call sees the
    /// points. Panics on a batch-built node (the orchestrator only routes
    /// inserts to live nodes; the TCP server rejects them with an error).
    pub fn insert_batch(&mut self, points: &[f32], labels: &[bool]) -> InsertReply {
        let store =
            Arc::clone(self.store.as_ref().expect("insert_batch on a batch-built node"));
        let out = store.append(points, labels);
        let mut reply = self.sync_workers();
        reply.accepted = out.accepted;
        reply.sealed_now = out.sealed_now;
        reply
    }

    /// Check the age-seal policy now (for a COMPLETELY quiet stream — any
    /// arriving insert already closes an overdue extent on its way in)
    /// and propagate the seal to the cores. Live nodes only. At cluster
    /// level this runs on every heartbeat (the shard dispatcher's
    /// periodic liveness probe answers through
    /// [`NodeHandle::heartbeat`](crate::coordinator::orchestrator::NodeHandle::heartbeat),
    /// which calls this), so quiet remote streams seal by age without
    /// anyone owning the `LocalNode` directly.
    pub fn poll_seal(&mut self) -> InsertReply {
        let store = Arc::clone(self.store.as_ref().expect("poll_seal on a batch-built node"));
        let sealed = store.poll_age();
        let mut reply = self.sync_workers();
        reply.sealed_now = sealed;
        reply
    }

    /// Fan an `Insert` to every core and gather the `p` acks (live
    /// nodes). Cores sync against the same store snapshot authority, so
    /// their acked counts must agree.
    fn sync_workers(&mut self) -> InsertReply {
        let store = Arc::clone(self.store.as_ref().expect("sync_workers on a batch-built node"));
        let seq = self.insert_seq;
        self.insert_seq += 1;
        for tx in &self.worker_tx {
            tx.send(WorkerMsg::Insert { seq }).expect("worker channel closed");
        }
        let (mut total, mut sealed_total) = (0u64, 0u64);
        for i in 0..self.p {
            let WorkerReplyMsg::Insert(ack) = self.reply_rx.recv().expect("worker died") else {
                unreachable!("query reply during insert");
            };
            debug_assert_eq!(ack.seq, seq);
            if i == 0 {
                total = ack.indexed;
                sealed_total = ack.sealed_segments;
            } else {
                debug_assert_eq!(ack.indexed, total, "cores disagree on indexed count");
                debug_assert_eq!(ack.sealed_segments, sealed_total, "cores disagree on seals");
            }
        }
        debug_assert_eq!(total, store.total(), "cores lag the store after sync");
        InsertReply { accepted: 0, total, sealed_now: 0, sealed_total }
    }

    /// Resolve one query: the Master broadcasts to all cores, gathers the
    /// `p` partial K-NN sets, and reduces them to the node-local K-NN.
    pub fn query(&mut self, q: &[f32]) -> NodeReply {
        let qid = self.next_qid;
        self.next_qid += 1;
        let start_ns = self.clock.now_ns();
        let q = Arc::new(q.to_vec());
        for tx in &self.worker_tx {
            tx.send(WorkerMsg::Query { qid, q: Arc::clone(&q) })
                .expect("worker channel closed");
        }
        let mut topk = TopK::new(self.k);
        let mut comparisons = vec![0u64; self.p];
        let mut inner_probes = 0u64;
        let mut tables = 0u32;
        let mut received = 0;
        while received < self.p {
            let WorkerReplyMsg::Single(reply) = self.reply_rx.recv().expect("worker died")
            else {
                unreachable!("batch reply during single query");
            };
            // Replies for stale qids are impossible: queries are strictly
            // sequential per node (ICU latency model — one query in flight).
            debug_assert_eq!(reply.qid, qid);
            comparisons[reply.core] = reply.stats.comparisons;
            inner_probes += reply.stats.inner_probes;
            tables = tables.saturating_add(reply.stats.tables);
            for n in reply.partial {
                topk.push_unique(n);
            }
            received += 1;
        }
        NodeReply {
            qid,
            neighbors: topk.into_sorted(),
            comparisons,
            inner_probes,
            scan_ns: self.clock.now_ns().saturating_sub(start_ns),
            tables,
            partial: false,
            shed: false,
        }
    }

    /// Resolve a block of `nq` queries (row-major `nq × dim`, shared
    /// flat buffer) in one Master round trip: the block is broadcast to
    /// all cores without copying, every core rides
    /// [`SlshIndex::query_batch`](crate::slsh::SlshIndex::query_batch)
    /// over its reused scratch arena, and the `p` flat batch replies are
    /// reduced per query. Per-query results are identical to calling
    /// [`query`] once per row (reduction is order-invariant).
    ///
    /// [`query`]: LocalNode::query
    pub fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Vec<NodeReply> {
        self.query_batch_plain(qs, nq, ProbeSpec::BASELINE)
    }

    /// Unbudgeted broadcast body shared by [`query_batch`] (baseline
    /// knobs) and [`query_batch_spec`] (per-request knobs).
    ///
    /// [`query_batch`]: LocalNode::query_batch
    /// [`query_batch_spec`]: LocalNode::query_batch_spec
    fn query_batch_plain(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        probe: ProbeSpec,
    ) -> Vec<NodeReply> {
        if nq == 0 {
            return Vec::new();
        }
        assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
        let qid0 = self.next_qid;
        self.next_qid += nq as u64;
        let start_ns = self.clock.now_ns();
        for tx in &self.worker_tx {
            tx.send(WorkerMsg::QueryBatch { qid0, qs: Arc::clone(&qs), nq, spec: probe })
                .expect("worker channel closed");
        }
        self.gather_batch(qid0, nq, start_ns)
    }

    /// Gather + reduce the `p` flat batch replies of one in-flight batch
    /// (plain or budget-enforced — the per-query `partial` flags ride the
    /// workers' [`QueryStats`](crate::slsh::QueryStats) either way and
    /// are always false on the plain path).
    fn gather_batch(&mut self, qid0: u64, nq: usize, start_ns: u64) -> Vec<NodeReply> {
        let mut topks: Vec<TopK> = (0..nq).map(|_| TopK::new(self.k)).collect();
        let mut comparisons: Vec<Vec<u64>> = (0..nq).map(|_| vec![0u64; self.p]).collect();
        let mut inner_probes = vec![0u64; nq];
        let mut tables = vec![0u32; nq];
        let mut partial = vec![false; nq];
        let mut received = 0;
        while received < self.p {
            let WorkerReplyMsg::Batch(reply) = self.reply_rx.recv().expect("worker died")
            else {
                unreachable!("single reply during batch query");
            };
            debug_assert_eq!(reply.qid0, qid0);
            debug_assert_eq!(reply.stats.len(), nq);
            for qi in 0..nq {
                let lo = reply.offsets[qi] as usize;
                let hi = reply.offsets[qi + 1] as usize;
                for n in &reply.neighbors[lo..hi] {
                    topks[qi].push_unique(*n);
                }
                comparisons[qi][reply.core] = reply.stats[qi].comparisons;
                inner_probes[qi] += reply.stats[qi].inner_probes;
                tables[qi] = tables[qi].saturating_add(reply.stats[qi].tables);
                partial[qi] |= reply.stats[qi].partial;
            }
            received += 1;
        }
        // One wall-time span for the whole batch (the node resolves it as
        // one unit); every reply carries it so any single reply can stand
        // in for the batch's scan span.
        let scan_ns = self.clock.now_ns().saturating_sub(start_ns);
        topks
            .into_iter()
            .zip(comparisons)
            .zip(inner_probes)
            .zip(tables)
            .zip(partial)
            .enumerate()
            .map(|(qi, ((((topk, comps), probes), tbls), part))| NodeReply {
                qid: qid0 + qi as u64,
                neighbors: topk.into_sorted(),
                comparisons: comps,
                inner_probes: probes,
                scan_ns,
                tables: tbls,
                partial: part,
                shed: false,
            })
            .collect()
    }

    /// Budget-aware batch entry point, mirroring the wire protocol's
    /// batch-with-budget frame: `budget` is the admission cut's remaining
    /// latency budget plus the enforcement policy, `class` its scheduling
    /// class. The node receives a cut the orchestrator's cutter already
    /// made, so no scheduling happens here — what IS node-side is the
    /// enforcement contract:
    ///
    /// * [`BudgetPolicy::LogOnly`] — full scan; overruns logged through
    ///   the shared accounting ([`note_batch_overrun`]), which both the
    ///   in-process path and the TCP server path go through, so local and
    ///   remote nodes report identically (pre-enforcement behavior,
    ///   bit-identical results).
    /// * [`BudgetPolicy::PartialResults`] — the deadline is anchored at
    ///   batch arrival on the node's clock (`now + remaining`), shipped
    ///   to every worker, and the scan early-exits when it passes;
    ///   replies carry per-query `partial` flags.
    /// * [`BudgetPolicy::Shed`] — a batch whose budget is already spent
    ///   on arrival (`remaining == 0`) is rejected before ANY scan work:
    ///   workers are never contacted, every reply is empty and flagged
    ///   `shed`. With budget remaining it behaves as `PartialResults`.
    pub fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Vec<NodeReply> {
        self.query_batch_spec(qs, nq, budget, class, ProbeSpec::BASELINE)
    }

    /// The node-side serving core: [`query_batch_budget`] with the
    /// request's probe knobs threaded through to every worker. A baseline
    /// spec (`probes == 1`, no comparison cap) takes the exact legacy
    /// paths, so default-knob requests are bit-identical to the pre-spec
    /// API; wider specs ride the same enforcement contract with each
    /// worker visiting `probes` buckets per owned table and truncating
    /// its candidate walk at `max_comparisons`.
    ///
    /// [`query_batch_budget`]: LocalNode::query_batch_budget
    pub fn query_batch_spec(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
        probe: ProbeSpec,
    ) -> Vec<NodeReply> {
        if budget.is_none() {
            return self.query_batch_plain(qs, nq, probe);
        }
        match budget.policy {
            BudgetPolicy::LogOnly => {
                let t0 = std::time::Instant::now();
                let replies = self.query_batch_plain(qs, nq, probe);
                note_batch_overrun(self.node_id, class, budget.remaining_us, t0.elapsed(), nq);
                replies
            }
            BudgetPolicy::Shed if budget.remaining_us == 0 => {
                // The deadline has already passed: a late answer is
                // worthless under the paper's latency model, so spend
                // ZERO scan time on it — empty replies, flagged.
                let qid0 = self.next_qid;
                self.next_qid += nq as u64;
                crate::log_info!(
                    "node",
                    "budget shed [{class}]: node {} rejected {nq} queries (0us remaining on arrival)",
                    self.node_id
                );
                (0..nq)
                    .map(|i| NodeReply {
                        qid: qid0 + i as u64,
                        neighbors: Vec::new(),
                        comparisons: vec![0u64; self.p],
                        inner_probes: 0,
                        scan_ns: 0,
                        tables: 0,
                        partial: true,
                        shed: true,
                    })
                    .collect()
            }
            BudgetPolicy::PartialResults | BudgetPolicy::Shed => {
                if nq == 0 {
                    return Vec::new();
                }
                assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
                let t0 = std::time::Instant::now();
                // Anchor at arrival: remaining was computed once at
                // dispatch, so every node (this one or a TCP-remote one)
                // enforces the same wall-clock deadline. The arrival
                // stamp doubles as the batch's scan-span start.
                let arrival_ns = self.clock.now_ns();
                let deadline_ns =
                    arrival_ns.saturating_add(budget.remaining_us.saturating_mul(1_000));
                let qid0 = self.next_qid;
                self.next_qid += nq as u64;
                for tx in &self.worker_tx {
                    tx.send(WorkerMsg::QueryBatchBudget {
                        qid0,
                        qs: Arc::clone(&qs),
                        nq,
                        deadline_ns,
                        spec: probe,
                    })
                    .expect("worker channel closed");
                }
                let replies = self.gather_batch(qid0, nq, arrival_ns);
                note_batch_overrun(self.node_id, class, budget.remaining_us, t0.elapsed(), nq);
                replies
            }
        }
    }
}

impl Drop for LocalNode {
    fn drop(&mut self) {
        for tx in &self.worker_tx {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_corpus, CorpusConfig, WindowSpec};
    use crate::engine::native::NativeEngine;
    use crate::engine::Metric;
    use crate::knn::exhaustive::pknn_query;
    use crate::lsh::family::LayerSpec;

    fn small_corpus() -> crate::data::Corpus {
        build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 4000, 50, 42))
    }

    fn params(data: &Dataset, m: usize, l: usize) -> SlshParams {
        let (lo, hi) = data.value_range();
        SlshParams::lsh_only(LayerSpec::outer_l1(data.dim, m, l, lo, hi, 7), 10)
    }

    fn native_engines(p: usize) -> Vec<Box<dyn DistanceEngine>> {
        (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
    }

    #[test]
    fn node_query_reduces_cores_and_counts() {
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 40, 16);
        let mut node = LocalNode::spawn(0, Arc::clone(&shard), 0, &params, 4, native_engines(4));
        assert_eq!(node.info().cores, 4);
        let q = corpus.queries.point(0);
        let reply = node.query(q);
        assert_eq!(reply.comparisons.len(), 4);
        assert!(!reply.neighbors.is_empty());
        assert!(reply.neighbors.windows(2).all(|w| w[0].dist <= w[1].dist));
        assert!(reply.neighbors.len() <= 10);
    }

    #[test]
    fn node_result_invariant_to_core_count() {
        // Partitioning tables across p cores must not change the node's
        // K-NN output (paper: parallelism does not influence prediction).
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 40, 12);
        let mut reference: Option<Vec<Vec<Neighbor>>> = None;
        for p in [1usize, 3, 4] {
            let mut node =
                LocalNode::spawn(0, Arc::clone(&shard), 0, &params, p, native_engines(p));
            let answers: Vec<Vec<Neighbor>> =
                (0..10).map(|i| node.query(corpus.queries.point(i)).neighbors).collect();
            match &reference {
                None => reference = Some(answers),
                Some(r) => assert_eq!(&answers, r, "p={p} changed results"),
            }
        }
    }

    #[test]
    fn node_neighbors_match_exhaustive_truth_on_hits() {
        // Every neighbor a node returns must carry the true L1 distance
        // (consistency between index candidates and scan).
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 30, 16);
        let mut node = LocalNode::spawn(0, Arc::clone(&shard), 0, &params, 2, native_engines(2));
        let engine = NativeEngine::new();
        for i in 0..5 {
            let q = corpus.queries.point(i);
            let reply = node.query(q);
            let truth = pknn_query(
                &engine,
                Metric::L1,
                q,
                &corpus.data.points,
                corpus.data.dim,
                &corpus.data.labels,
                10,
                1,
            );
            let truth_dist: std::collections::HashMap<u64, f32> =
                truth.neighbors.iter().map(|n| (n.id, n.dist)).collect();
            for n in &reply.neighbors {
                if let Some(&d) = truth_dist.get(&n.id) {
                    assert!((n.dist - d).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn query_batch_matches_sequential_queries_across_core_counts() {
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 40, 12);
        for p in [1usize, 3] {
            // Sequential reference on one node, batched on a fresh node
            // (same spec ⇒ same tables), across batch sizes incl. 1 and
            // non-multiples of the scan/hash tiles.
            let mut seq_node =
                LocalNode::spawn(0, Arc::clone(&shard), 0, &params, p, native_engines(p));
            let mut batch_node =
                LocalNode::spawn(0, Arc::clone(&shard), 0, &params, p, native_engines(p));
            let mut qi = 0usize;
            for nq in [1usize, 3, 7] {
                let mut flat = Vec::new();
                for i in qi..qi + nq {
                    flat.extend_from_slice(corpus.queries.point(i));
                }
                let batched = batch_node.query_batch(Arc::new(flat), nq);
                assert_eq!(batched.len(), nq);
                for j in 0..nq {
                    let seq = seq_node.query(corpus.queries.point(qi + j));
                    assert_eq!(batched[j].neighbors, seq.neighbors, "p={p} nq={nq} j={j}");
                    assert_eq!(batched[j].comparisons, seq.comparisons);
                    assert_eq!(batched[j].inner_probes, seq.inner_probes);
                }
                qi += nq;
            }
        }
    }

    #[test]
    fn live_node_serves_inserts_then_queries() {
        use crate::util::clock::MockClock;
        let corpus = small_corpus();
        let params = params(&corpus.data, 30, 12);
        let clock = Arc::new(MockClock::new(0));
        let mut node = LocalNode::spawn_live(
            0,
            7_000,
            &params,
            3,
            native_engines(3),
            clock,
            crate::slsh::SealPolicy::by_size(1000),
        );
        assert!(node.is_live());
        assert_eq!(node.info().shard_len, 0);
        // Empty node answers empty.
        let empty = node.query(corpus.queries.point(0));
        assert!(empty.neighbors.is_empty());
        // Insert 2500 points in uneven batches; seals trip at 1000/2000.
        let d = &corpus.data;
        let mut at = 0usize;
        let mut sealed = 0u64;
        for take in [700usize, 700, 700, 400] {
            let r = node.insert_batch(
                &d.points[at * d.dim..(at + take) * d.dim],
                &d.labels[at..at + take],
            );
            at += take;
            sealed = r.sealed_total;
            assert_eq!(r.accepted, take as u64);
            assert_eq!(r.total, at as u64);
        }
        assert_eq!(sealed, 2);
        // Every inserted point finds itself, with the node's id base.
        for probe in [0usize, 999, 1000, 2499] {
            let reply = node.query(d.point(probe));
            assert!(
                reply.neighbors.iter().any(|n| n.id == 7_000 + probe as u64 && n.dist == 0.0),
                "probe {probe}: {:?}",
                reply.neighbors
            );
        }
    }

    #[test]
    fn live_node_result_invariant_to_core_count() {
        use crate::util::clock::MockClock;
        let corpus = small_corpus();
        let params = params(&corpus.data, 40, 12);
        let d = &corpus.data;
        let mut reference: Option<Vec<Vec<Neighbor>>> = None;
        for p in [1usize, 3] {
            let mut node = LocalNode::spawn_live(
                0,
                0,
                &params,
                p,
                native_engines(p),
                Arc::new(MockClock::new(0)),
                crate::slsh::SealPolicy::by_size(900),
            );
            node.insert_batch(&d.points[..2000 * d.dim], &d.labels[..2000]);
            let answers: Vec<Vec<Neighbor>> =
                (0..10).map(|i| node.query(corpus.queries.point(i)).neighbors).collect();
            match &reference {
                None => reference = Some(answers),
                Some(r) => assert_eq!(&answers, r, "p={p} changed results"),
            }
        }
    }

    #[test]
    fn id_base_offsets_ids() {
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.shard(0..1000));
        let params = params(&corpus.data, 30, 8);
        let mut node =
            LocalNode::spawn(1, Arc::clone(&shard), 5000, &params, 2, native_engines(2));
        let reply = node.query(shard.point(3));
        assert!(reply.neighbors.iter().any(|n| n.id == 5003), "{:?}", reply.neighbors);
        assert!(reply.neighbors.iter().all(|n| (5000..6000).contains(&n.id)));
    }
}
