//! An SLSH node (paper Figure 2): `p` core-workers over a shared-memory
//! shard, with a Master gather/reduce. In the cloud deployment a node is
//! one VM; here it is a thread group (comparisons — the paper's speed
//! metric — are partitioning-determined, so the simulation reproduces the
//! tables exactly; see DESIGN.md §Substitutions).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::admission::{note_batch_overrun, Budget, BudgetPolicy, Class};
use crate::data::Dataset;
use crate::engine::DistanceEngine;
use crate::knn::heap::{Neighbor, TopK};
use crate::node::worker::{owned_tables, run_worker, WorkerMsg, WorkerReplyMsg};
use crate::slsh::SlshParams;
use crate::util::clock::{Clock, SystemClock};

/// A node's answer to one query — what travels back to the Orchestrator.
#[derive(Debug, Clone)]
pub struct NodeReply {
    pub qid: u64,
    /// The node-local K-NN (already reduced across its cores).
    pub neighbors: Vec<Neighbor>,
    /// Comparisons per core for this query (the paper reports the max
    /// across all processors of all nodes).
    pub comparisons: Vec<u64>,
    /// Inner-layer probes per core (diagnostics).
    pub inner_probes: u64,
    /// True when budget enforcement stopped at least one core before it
    /// covered all its tables. `neighbors` is then the union of
    /// *per-core table prefixes* (each core stops on a prefix of its OWN
    /// owned tables; cores progress independently), so every returned
    /// neighbor carries its true distance and appears in the unenforced
    /// candidate walk — but the union is not in general a prefix of the
    /// node's full table order. Always false without enforcement.
    pub partial: bool,
    /// True when the node shed the whole batch before any scan work
    /// (budget already spent on arrival under `BudgetPolicy::Shed`).
    /// Implies `partial`.
    pub shed: bool,
}

/// Construction-time information reported by a node.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub node_id: usize,
    pub shard_len: usize,
    pub cores: usize,
    pub build_ms: f64,
}

/// One in-process SLSH node: `p` worker threads + shared shard.
pub struct LocalNode {
    node_id: usize,
    worker_tx: Vec<Sender<WorkerMsg>>,
    reply_rx: Receiver<WorkerReplyMsg>,
    handles: Vec<JoinHandle<()>>,
    k: usize,
    p: usize,
    info: NodeInfo,
    next_qid: u64,
    /// Budget-enforcement time source (shared with every worker); a node
    /// anchors a cut's deadline at batch *arrival* on this clock.
    clock: Arc<dyn Clock>,
}

impl LocalNode {
    /// Spawn the node: builds all owned tables in parallel across `p`
    /// worker threads (each core constructs its tables independently).
    ///
    /// `engines` supplies one distance engine per core (native or XLA
    /// handles — they may differ, e.g. for ablation).
    pub fn spawn(
        node_id: usize,
        shard: Arc<Dataset>,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        engines: Vec<Box<dyn DistanceEngine>>,
    ) -> LocalNode {
        LocalNode::spawn_with_clock(
            node_id,
            shard,
            id_base,
            params,
            p,
            engines,
            Arc::new(SystemClock::new()),
        )
    }

    /// [`spawn`](LocalNode::spawn) with an injected [`Clock`] — the
    /// budget-enforcement tests drive nodes with `MockClock`/`TickClock`
    /// so partial-scan decisions are deterministic.
    pub fn spawn_with_clock(
        node_id: usize,
        shard: Arc<Dataset>,
        id_base: u64,
        params: &SlshParams,
        p: usize,
        mut engines: Vec<Box<dyn DistanceEngine>>,
        clock: Arc<dyn Clock>,
    ) -> LocalNode {
        assert_eq!(engines.len(), p, "need one engine per core");
        let t0 = std::time::Instant::now();
        let (reply_tx, reply_rx) = channel::<WorkerReplyMsg>();
        let (ready_tx, ready_rx) = channel::<usize>();
        let mut worker_tx = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for core in 0..p {
            let (tx, rx) = channel::<WorkerMsg>();
            worker_tx.push(tx);
            let shard_c = Arc::clone(&shard);
            let params_c = params.clone();
            let tables = owned_tables(params.outer.l, p, core);
            let engine = engines.remove(0);
            let clock_c = Arc::clone(&clock);
            let reply_tx_c = reply_tx.clone();
            let ready_c = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("node{node_id}-core{core}"))
                .spawn(move || {
                    run_worker(
                        core, shard_c, id_base, params_c, tables, engine, clock_c, rx,
                        reply_tx_c, ready_c,
                    )
                })
                .expect("spawning worker");
            handles.push(handle);
        }
        drop(ready_tx);
        // Wait until every core finished building its tables.
        let mut built = 0;
        while built < p {
            ready_rx.recv().expect("worker died during build");
            built += 1;
        }
        let info = NodeInfo {
            node_id,
            shard_len: shard.len(),
            cores: p,
            build_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        LocalNode {
            node_id,
            worker_tx,
            reply_rx,
            handles,
            k: params.k,
            p,
            info,
            next_qid: 0,
            clock,
        }
    }

    pub fn info(&self) -> &NodeInfo {
        &self.info
    }

    pub fn node_id(&self) -> usize {
        self.node_id
    }

    /// Resolve one query: the Master broadcasts to all cores, gathers the
    /// `p` partial K-NN sets, and reduces them to the node-local K-NN.
    pub fn query(&mut self, q: &[f32]) -> NodeReply {
        let qid = self.next_qid;
        self.next_qid += 1;
        let q = Arc::new(q.to_vec());
        for tx in &self.worker_tx {
            tx.send(WorkerMsg::Query { qid, q: Arc::clone(&q) })
                .expect("worker channel closed");
        }
        let mut topk = TopK::new(self.k);
        let mut comparisons = vec![0u64; self.p];
        let mut inner_probes = 0u64;
        let mut received = 0;
        while received < self.p {
            let WorkerReplyMsg::Single(reply) = self.reply_rx.recv().expect("worker died")
            else {
                unreachable!("batch reply during single query");
            };
            // Replies for stale qids are impossible: queries are strictly
            // sequential per node (ICU latency model — one query in flight).
            debug_assert_eq!(reply.qid, qid);
            comparisons[reply.core] = reply.stats.comparisons;
            inner_probes += reply.stats.inner_probes;
            for n in reply.partial {
                topk.push_unique(n);
            }
            received += 1;
        }
        NodeReply {
            qid,
            neighbors: topk.into_sorted(),
            comparisons,
            inner_probes,
            partial: false,
            shed: false,
        }
    }

    /// Resolve a block of `nq` queries (row-major `nq × dim`, shared
    /// flat buffer) in one Master round trip: the block is broadcast to
    /// all cores without copying, every core rides
    /// [`SlshIndex::query_batch`](crate::slsh::SlshIndex::query_batch)
    /// over its reused scratch arena, and the `p` flat batch replies are
    /// reduced per query. Per-query results are identical to calling
    /// [`query`] once per row (reduction is order-invariant).
    ///
    /// [`query`]: LocalNode::query
    pub fn query_batch(&mut self, qs: Arc<Vec<f32>>, nq: usize) -> Vec<NodeReply> {
        if nq == 0 {
            return Vec::new();
        }
        assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
        let qid0 = self.next_qid;
        self.next_qid += nq as u64;
        for tx in &self.worker_tx {
            tx.send(WorkerMsg::QueryBatch { qid0, qs: Arc::clone(&qs), nq })
                .expect("worker channel closed");
        }
        self.gather_batch(qid0, nq)
    }

    /// Gather + reduce the `p` flat batch replies of one in-flight batch
    /// (plain or budget-enforced — the per-query `partial` flags ride the
    /// workers' [`QueryStats`](crate::slsh::QueryStats) either way and
    /// are always false on the plain path).
    fn gather_batch(&mut self, qid0: u64, nq: usize) -> Vec<NodeReply> {
        let mut topks: Vec<TopK> = (0..nq).map(|_| TopK::new(self.k)).collect();
        let mut comparisons: Vec<Vec<u64>> = (0..nq).map(|_| vec![0u64; self.p]).collect();
        let mut inner_probes = vec![0u64; nq];
        let mut partial = vec![false; nq];
        let mut received = 0;
        while received < self.p {
            let WorkerReplyMsg::Batch(reply) = self.reply_rx.recv().expect("worker died")
            else {
                unreachable!("single reply during batch query");
            };
            debug_assert_eq!(reply.qid0, qid0);
            debug_assert_eq!(reply.stats.len(), nq);
            for qi in 0..nq {
                let lo = reply.offsets[qi] as usize;
                let hi = reply.offsets[qi + 1] as usize;
                for n in &reply.neighbors[lo..hi] {
                    topks[qi].push_unique(*n);
                }
                comparisons[qi][reply.core] = reply.stats[qi].comparisons;
                inner_probes[qi] += reply.stats[qi].inner_probes;
                partial[qi] |= reply.stats[qi].partial;
            }
            received += 1;
        }
        topks
            .into_iter()
            .zip(comparisons)
            .zip(inner_probes)
            .zip(partial)
            .enumerate()
            .map(|(qi, (((topk, comps), probes), part))| NodeReply {
                qid: qid0 + qi as u64,
                neighbors: topk.into_sorted(),
                comparisons: comps,
                inner_probes: probes,
                partial: part,
                shed: false,
            })
            .collect()
    }

    /// Budget-aware batch entry point, mirroring the wire protocol's
    /// batch-with-budget frame: `budget` is the admission cut's remaining
    /// latency budget plus the enforcement policy, `class` its scheduling
    /// class. The node receives a cut the orchestrator's cutter already
    /// made, so no scheduling happens here — what IS node-side is the
    /// enforcement contract:
    ///
    /// * [`BudgetPolicy::LogOnly`] — full scan; overruns logged through
    ///   the shared accounting ([`note_batch_overrun`]), which both the
    ///   in-process path and the TCP server path go through, so local and
    ///   remote nodes report identically (pre-enforcement behavior,
    ///   bit-identical results).
    /// * [`BudgetPolicy::PartialResults`] — the deadline is anchored at
    ///   batch arrival on the node's clock (`now + remaining`), shipped
    ///   to every worker, and the scan early-exits when it passes;
    ///   replies carry per-query `partial` flags.
    /// * [`BudgetPolicy::Shed`] — a batch whose budget is already spent
    ///   on arrival (`remaining == 0`) is rejected before ANY scan work:
    ///   workers are never contacted, every reply is empty and flagged
    ///   `shed`. With budget remaining it behaves as `PartialResults`.
    pub fn query_batch_budget(
        &mut self,
        qs: Arc<Vec<f32>>,
        nq: usize,
        budget: Budget,
        class: Class,
    ) -> Vec<NodeReply> {
        if budget.is_none() {
            return self.query_batch(qs, nq);
        }
        match budget.policy {
            BudgetPolicy::LogOnly => {
                let t0 = std::time::Instant::now();
                let replies = self.query_batch(qs, nq);
                note_batch_overrun(self.node_id, class, budget.remaining_us, t0.elapsed(), nq);
                replies
            }
            BudgetPolicy::Shed if budget.remaining_us == 0 => {
                // The deadline has already passed: a late answer is
                // worthless under the paper's latency model, so spend
                // ZERO scan time on it — empty replies, flagged.
                let qid0 = self.next_qid;
                self.next_qid += nq as u64;
                crate::log_info!(
                    "node",
                    "budget shed [{class}]: node {} rejected {nq} queries (0us remaining on arrival)",
                    self.node_id
                );
                (0..nq)
                    .map(|i| NodeReply {
                        qid: qid0 + i as u64,
                        neighbors: Vec::new(),
                        comparisons: vec![0u64; self.p],
                        inner_probes: 0,
                        partial: true,
                        shed: true,
                    })
                    .collect()
            }
            BudgetPolicy::PartialResults | BudgetPolicy::Shed => {
                if nq == 0 {
                    return Vec::new();
                }
                assert_eq!(qs.len() % nq, 0, "query block not a multiple of nq");
                let t0 = std::time::Instant::now();
                // Anchor at arrival: remaining was computed once at
                // dispatch, so every node (this one or a TCP-remote one)
                // enforces the same wall-clock deadline.
                let deadline_ns =
                    self.clock.now_ns().saturating_add(budget.remaining_us.saturating_mul(1_000));
                let qid0 = self.next_qid;
                self.next_qid += nq as u64;
                for tx in &self.worker_tx {
                    tx.send(WorkerMsg::QueryBatchBudget {
                        qid0,
                        qs: Arc::clone(&qs),
                        nq,
                        deadline_ns,
                    })
                    .expect("worker channel closed");
                }
                let replies = self.gather_batch(qid0, nq);
                note_batch_overrun(self.node_id, class, budget.remaining_us, t0.elapsed(), nq);
                replies
            }
        }
    }
}

impl Drop for LocalNode {
    fn drop(&mut self) {
        for tx in &self.worker_tx {
            let _ = tx.send(WorkerMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_corpus, CorpusConfig, WindowSpec};
    use crate::engine::native::NativeEngine;
    use crate::engine::Metric;
    use crate::knn::exhaustive::pknn_query;
    use crate::lsh::family::LayerSpec;

    fn small_corpus() -> crate::data::Corpus {
        build_corpus(&CorpusConfig::new(WindowSpec::ahe_51_5c(), 4000, 50, 42))
    }

    fn params(data: &Dataset, m: usize, l: usize) -> SlshParams {
        let (lo, hi) = data.value_range();
        SlshParams::lsh_only(LayerSpec::outer_l1(data.dim, m, l, lo, hi, 7), 10)
    }

    fn native_engines(p: usize) -> Vec<Box<dyn DistanceEngine>> {
        (0..p).map(|_| Box::new(NativeEngine::new()) as Box<dyn DistanceEngine>).collect()
    }

    #[test]
    fn node_query_reduces_cores_and_counts() {
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 40, 16);
        let mut node = LocalNode::spawn(0, Arc::clone(&shard), 0, &params, 4, native_engines(4));
        assert_eq!(node.info().cores, 4);
        let q = corpus.queries.point(0);
        let reply = node.query(q);
        assert_eq!(reply.comparisons.len(), 4);
        assert!(!reply.neighbors.is_empty());
        assert!(reply.neighbors.windows(2).all(|w| w[0].dist <= w[1].dist));
        assert!(reply.neighbors.len() <= 10);
    }

    #[test]
    fn node_result_invariant_to_core_count() {
        // Partitioning tables across p cores must not change the node's
        // K-NN output (paper: parallelism does not influence prediction).
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 40, 12);
        let mut reference: Option<Vec<Vec<Neighbor>>> = None;
        for p in [1usize, 3, 4] {
            let mut node =
                LocalNode::spawn(0, Arc::clone(&shard), 0, &params, p, native_engines(p));
            let answers: Vec<Vec<Neighbor>> =
                (0..10).map(|i| node.query(corpus.queries.point(i)).neighbors).collect();
            match &reference {
                None => reference = Some(answers),
                Some(r) => assert_eq!(&answers, r, "p={p} changed results"),
            }
        }
    }

    #[test]
    fn node_neighbors_match_exhaustive_truth_on_hits() {
        // Every neighbor a node returns must carry the true L1 distance
        // (consistency between index candidates and scan).
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 30, 16);
        let mut node = LocalNode::spawn(0, Arc::clone(&shard), 0, &params, 2, native_engines(2));
        let engine = NativeEngine::new();
        for i in 0..5 {
            let q = corpus.queries.point(i);
            let reply = node.query(q);
            let truth = pknn_query(
                &engine,
                Metric::L1,
                q,
                &corpus.data.points,
                corpus.data.dim,
                &corpus.data.labels,
                10,
                1,
            );
            let truth_dist: std::collections::HashMap<u64, f32> =
                truth.neighbors.iter().map(|n| (n.id, n.dist)).collect();
            for n in &reply.neighbors {
                if let Some(&d) = truth_dist.get(&n.id) {
                    assert!((n.dist - d).abs() < 1e-3);
                }
            }
        }
    }

    #[test]
    fn query_batch_matches_sequential_queries_across_core_counts() {
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.clone());
        let params = params(&corpus.data, 40, 12);
        for p in [1usize, 3] {
            // Sequential reference on one node, batched on a fresh node
            // (same spec ⇒ same tables), across batch sizes incl. 1 and
            // non-multiples of the scan/hash tiles.
            let mut seq_node =
                LocalNode::spawn(0, Arc::clone(&shard), 0, &params, p, native_engines(p));
            let mut batch_node =
                LocalNode::spawn(0, Arc::clone(&shard), 0, &params, p, native_engines(p));
            let mut qi = 0usize;
            for nq in [1usize, 3, 7] {
                let mut flat = Vec::new();
                for i in qi..qi + nq {
                    flat.extend_from_slice(corpus.queries.point(i));
                }
                let batched = batch_node.query_batch(Arc::new(flat), nq);
                assert_eq!(batched.len(), nq);
                for j in 0..nq {
                    let seq = seq_node.query(corpus.queries.point(qi + j));
                    assert_eq!(batched[j].neighbors, seq.neighbors, "p={p} nq={nq} j={j}");
                    assert_eq!(batched[j].comparisons, seq.comparisons);
                    assert_eq!(batched[j].inner_probes, seq.inner_probes);
                }
                qi += nq;
            }
        }
    }

    #[test]
    fn id_base_offsets_ids() {
        let corpus = small_corpus();
        let shard = Arc::new(corpus.data.shard(0..1000));
        let params = params(&corpus.data, 30, 8);
        let mut node =
            LocalNode::spawn(1, Arc::clone(&shard), 5000, &params, 2, native_engines(2));
        let reply = node.query(shard.point(3));
        assert!(reply.neighbors.iter().any(|n| n.id == 5003), "{:?}", reply.neighbors);
        assert!(reply.neighbors.iter().all(|n| (5000..6000).contains(&n.id)));
    }
}
