//! Per-core worker (paper Figure 2): one long-lived thread per simulated
//! core `P_i`, owning `O(L_out / p)` outer tables (and their inner
//! indices), a reusable query-scratch arena, and a comparison counter.
//! The shard's points live in shared memory — a static `Arc<Dataset>`
//! slice for batch-built nodes, or the node's growable
//! [`LiveStore`] for live (streaming) nodes; buckets hold local ids into
//! it.
//!
//! Workers serve both single queries (the ICU one-in-flight latency
//! model) and query batches: a batch is resolved through
//! [`SlshIndex::query_batch`] (batch-built) or
//! [`LiveIndex::query_batch`] (live, cross-segment merge) — batched
//! hashing + pooled scratch — and answered with ONE flat
//! [`WorkerBatchReply`] per batch, so the reply path allocates per batch,
//! not per query. Budget-enforced batches
//! ([`WorkerMsg::QueryBatchBudget`]) carry an absolute deadline on the
//! node's injected clock and resolve through the cancellable twins — the
//! worker stops consulting tables (and, live, whole segments) the moment
//! the deadline is blown and flags the affected queries `partial` in
//! their [`QueryStats`].
//!
//! Live workers additionally serve [`WorkerMsg::Insert`]: the node master
//! has already appended the points to the shared store; the worker
//! catches its own tables up ([`LiveIndex::sync`] — hashing fresh rows
//! into its delta, sealing segments the store closed) and acks. Queries
//! and inserts are serialized per worker by the inbox, so a query
//! admitted after an insert ack always sees those points.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::data::Dataset;
use crate::engine::{DistanceEngine, ScanCancel};
use crate::knn::heap::Neighbor;
use crate::lsh::probe::ProbeSpec;
use crate::slsh::{
    BatchOutput, LiveIndex, LiveScratch, LiveStore, QueryScratch, QueryStats, SlshIndex,
    SlshParams,
};
use crate::util::clock::Clock;

/// Messages a worker accepts.
pub enum WorkerMsg {
    /// Resolve a query; reply through the node's gather channel.
    Query { qid: u64, q: Arc<Vec<f32>> },
    /// Resolve a block of queries (`qs` row-major `nq × dim`, query `i`
    /// has id `qid0 + i`) under the request's probe/budget knobs
    /// (`ProbeSpec::BASELINE` = the legacy path, bit-identical).
    QueryBatch { qid0: u64, qs: Arc<Vec<f32>>, nq: usize, spec: ProbeSpec },
    /// Resolve a block under budget enforcement: stop scanning when the
    /// worker's clock reaches `deadline_ns` and report partial results
    /// (see [`SlshIndex::query_batch_cancel`]), with the probe knobs
    /// applied the same way as [`WorkerMsg::QueryBatch`].
    QueryBatchBudget {
        qid0: u64,
        qs: Arc<Vec<f32>>,
        nq: usize,
        deadline_ns: u64,
        spec: ProbeSpec,
    },
    /// Live nodes only: catch this core's tables up with the node store
    /// (hash newly appended points, seal closed extents) and ack with
    /// sequence number `seq`.
    Insert { seq: u64 },
    /// Drain and exit.
    Shutdown,
}

/// One worker's partial answer to a single query.
pub struct WorkerReply {
    pub core: usize,
    pub qid: u64,
    pub partial: Vec<Neighbor>,
    pub stats: QueryStats,
}

/// One worker's partial answers to a whole batch, CSR-flat: query `i`'s
/// neighbors are `neighbors[offsets[i] as usize..offsets[i + 1] as usize]`.
pub struct WorkerBatchReply {
    pub core: usize,
    pub qid0: u64,
    pub neighbors: Vec<Neighbor>,
    pub offsets: Vec<u32>,
    pub stats: Vec<QueryStats>,
}

/// One worker's ingest acknowledgment (live nodes).
pub struct WorkerInsertAck {
    pub core: usize,
    pub seq: u64,
    /// Points this core has fully indexed after the sync.
    pub indexed: u64,
    /// Sealed segments this core holds after the sync.
    pub sealed_segments: u64,
}

/// What flows back over the node's gather channel.
pub enum WorkerReplyMsg {
    Single(WorkerReply),
    Batch(WorkerBatchReply),
    Insert(WorkerInsertAck),
}

/// How a worker obtains its index — the batch-built / live split.
pub enum WorkerSpec {
    /// Build a frozen [`SlshIndex`] over a static shard slice.
    Static { shard: Arc<Dataset>, tables: Vec<usize> },
    /// Follow the node's growable [`LiveStore`] with a [`LiveIndex`].
    Live { store: Arc<LiveStore>, tables: Vec<usize> },
}

/// Table indices owned by core `i` of `p`: `{t : t ≡ i (mod p)}` — the
/// paper's O(L/p)-tables-per-processor round-robin split.
pub fn owned_tables(l: usize, p: usize, core: usize) -> Vec<usize> {
    (0..l).filter(|t| t % p == core).collect()
}

/// A worker's resolved index + scratch, behind one dispatch point so the
/// message loop stays shape-agnostic.
enum WorkerIndex {
    Static { index: SlshIndex, shard: Arc<Dataset>, scratch: QueryScratch },
    Live { live: LiveIndex, scratch: LiveScratch },
}

impl WorkerIndex {
    fn resolve(
        &mut self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        id_base: u64,
        spec: ProbeSpec,
        out: &mut BatchOutput,
        cancel: Option<&ScanCancel>,
    ) {
        // Both spec entry points dispatch the baseline spec to the exact
        // legacy bodies, so the default-knob path is unchanged code.
        match self {
            WorkerIndex::Static { index, shard, scratch } => index.query_batch_spec(
                engine,
                qs,
                &shard.points,
                &shard.labels,
                id_base,
                spec,
                scratch,
                out,
                cancel,
            ),
            WorkerIndex::Live { live, scratch } => {
                live.query_batch_spec(engine, qs, scratch, out, spec, cancel)
            }
        }
    }
}

/// Worker main loop: build/attach the owned tables, then serve queries
/// (and, live, inserts).
///
/// `ready` fires once construction finishes (the node master waits for all
/// cores before declaring the node built — table construction is entirely
/// parallel, per the paper).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    core: usize,
    spec: WorkerSpec,
    id_base: u64,
    params: SlshParams,
    engine: Box<dyn DistanceEngine>,
    clock: Arc<dyn Clock>,
    rx: Receiver<WorkerMsg>,
    reply_tx: Sender<WorkerReplyMsg>,
    ready: Sender<usize>,
) {
    let mut backend = match spec {
        WorkerSpec::Static { shard, tables } => {
            let index = SlshIndex::build(&params, &*shard, &tables);
            let scratch = QueryScratch::new(shard.len().max(1));
            WorkerIndex::Static { index, shard, scratch }
        }
        WorkerSpec::Live { store, tables } => {
            let live = LiveIndex::with_store(&params, &tables, store, id_base);
            live.sync(); // the store may be pre-populated
            WorkerIndex::Live { live, scratch: LiveScratch::new() }
        }
    };
    let mut batch_out = BatchOutput::new();
    let _ = ready.send(core);
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Query { qid, q } => {
                backend.resolve(
                    engine.as_ref(),
                    &q,
                    id_base,
                    ProbeSpec::BASELINE,
                    &mut batch_out,
                    None,
                );
                let reply = WorkerReply {
                    core,
                    qid,
                    partial: batch_out.neighbors(0).to_vec(),
                    stats: batch_out.stats(0),
                };
                if reply_tx.send(WorkerReplyMsg::Single(reply)).is_err() {
                    break; // node gone
                }
            }
            WorkerMsg::QueryBatch { qid0, qs, nq, spec } => {
                backend.resolve(engine.as_ref(), &qs, id_base, spec, &mut batch_out, None);
                debug_assert_eq!(batch_out.len(), nq);
                if send_batch_reply(&reply_tx, core, qid0, &batch_out).is_err() {
                    break;
                }
            }
            WorkerMsg::QueryBatchBudget { qid0, qs, nq, deadline_ns, spec } => {
                let cancel = ScanCancel::until(Arc::clone(&clock), deadline_ns);
                backend.resolve(
                    engine.as_ref(),
                    &qs,
                    id_base,
                    spec,
                    &mut batch_out,
                    Some(&cancel),
                );
                debug_assert_eq!(batch_out.len(), nq);
                if send_batch_reply(&reply_tx, core, qid0, &batch_out).is_err() {
                    break;
                }
            }
            WorkerMsg::Insert { seq } => {
                let WorkerIndex::Live { live, .. } = &backend else {
                    unreachable!("Insert sent to a batch-built worker");
                };
                live.sync();
                let ack = WorkerInsertAck {
                    core,
                    seq,
                    indexed: live.len() as u64,
                    sealed_segments: live.sealed_segments() as u64,
                };
                if reply_tx.send(WorkerReplyMsg::Insert(ack)).is_err() {
                    break;
                }
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

/// Ship one flat batch reply (shared by the plain and budget arms).
fn send_batch_reply(
    reply_tx: &Sender<WorkerReplyMsg>,
    core: usize,
    qid0: u64,
    batch_out: &BatchOutput,
) -> Result<(), std::sync::mpsc::SendError<WorkerReplyMsg>> {
    let (neighbors, offsets, stats) = batch_out.flat();
    reply_tx.send(WorkerReplyMsg::Batch(WorkerBatchReply {
        core,
        qid0,
        neighbors: neighbors.to_vec(),
        offsets: offsets.to_vec(),
        stats: stats.to_vec(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_tables_partition_exactly() {
        for (l, p) in [(120usize, 8usize), (12, 5), (7, 7), (3, 8)] {
            let mut seen = vec![false; l];
            for core in 0..p {
                for t in owned_tables(l, p, core) {
                    assert!(!seen[t], "table {t} owned twice");
                    seen[t] = true;
                }
            }
            assert!(seen.iter().all(|s| *s), "unowned tables for l={l} p={p}");
            // Balance: sizes differ by at most 1.
            let sizes: Vec<usize> = (0..p).map(|c| owned_tables(l, p, c).len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
        }
    }
}
