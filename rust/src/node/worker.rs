//! Per-core worker (paper Figure 2): one long-lived thread per simulated
//! core `P_i`, owning `O(L_out / p)` outer tables (and their inner
//! indices), a reusable query-scratch arena, and a comparison counter.
//! The shard's points live in shared memory (`Arc<Dataset>`); buckets
//! hold local ids into it.
//!
//! Workers serve both single queries (the ICU one-in-flight latency
//! model) and query batches: a batch is resolved through
//! [`SlshIndex::query_batch`] — batched hashing + pooled scratch — and
//! answered with ONE flat [`WorkerBatchReply`] per batch, so the reply
//! path allocates per batch, not per query. Budget-enforced batches
//! ([`WorkerMsg::QueryBatchBudget`]) carry an absolute deadline on the
//! node's injected clock and resolve through
//! [`SlshIndex::query_batch_cancel`] — the worker stops consulting
//! tables the moment the deadline is blown and flags the affected
//! queries `partial` in their [`QueryStats`].

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::data::Dataset;
use crate::engine::{DistanceEngine, ScanCancel};
use crate::knn::heap::Neighbor;
use crate::slsh::{BatchOutput, QueryScratch, QueryStats, SlshIndex, SlshParams};
use crate::util::clock::Clock;

/// Messages a worker accepts.
pub enum WorkerMsg {
    /// Resolve a query; reply through the node's gather channel.
    Query { qid: u64, q: Arc<Vec<f32>> },
    /// Resolve a block of queries (`qs` row-major `nq × dim`, query `i`
    /// has id `qid0 + i`).
    QueryBatch { qid0: u64, qs: Arc<Vec<f32>>, nq: usize },
    /// Resolve a block under budget enforcement: stop scanning when the
    /// worker's clock reaches `deadline_ns` and report partial results
    /// (see [`SlshIndex::query_batch_cancel`]).
    QueryBatchBudget { qid0: u64, qs: Arc<Vec<f32>>, nq: usize, deadline_ns: u64 },
    /// Drain and exit.
    Shutdown,
}

/// One worker's partial answer to a single query.
pub struct WorkerReply {
    pub core: usize,
    pub qid: u64,
    pub partial: Vec<Neighbor>,
    pub stats: QueryStats,
}

/// One worker's partial answers to a whole batch, CSR-flat: query `i`'s
/// neighbors are `neighbors[offsets[i] as usize..offsets[i + 1] as usize]`.
pub struct WorkerBatchReply {
    pub core: usize,
    pub qid0: u64,
    pub neighbors: Vec<Neighbor>,
    pub offsets: Vec<u32>,
    pub stats: Vec<QueryStats>,
}

/// What flows back over the node's gather channel.
pub enum WorkerReplyMsg {
    Single(WorkerReply),
    Batch(WorkerBatchReply),
}

/// Table indices owned by core `i` of `p`: `{t : t ≡ i (mod p)}` — the
/// paper's O(L/p)-tables-per-processor round-robin split.
pub fn owned_tables(l: usize, p: usize, core: usize) -> Vec<usize> {
    (0..l).filter(|t| t % p == core).collect()
}

/// Worker main loop: build the owned tables, then serve queries.
///
/// `ready` fires once construction finishes (the node master waits for all
/// cores before declaring the node built — table construction is entirely
/// parallel, per the paper).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    core: usize,
    shard: Arc<Dataset>,
    id_base: u64,
    params: SlshParams,
    tables: Vec<usize>,
    engine: Box<dyn DistanceEngine>,
    clock: Arc<dyn Clock>,
    rx: Receiver<WorkerMsg>,
    reply_tx: Sender<WorkerReplyMsg>,
    ready: Sender<usize>,
) {
    let index = SlshIndex::build(&params, &*shard, &tables);
    let mut scratch = QueryScratch::new(shard.len().max(1));
    let mut batch_out = BatchOutput::new();
    let _ = ready.send(core);
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Query { qid, q } => {
                index.query_batch(
                    engine.as_ref(),
                    &q,
                    &shard.points,
                    &shard.labels,
                    id_base,
                    &mut scratch,
                    &mut batch_out,
                );
                let reply = WorkerReply {
                    core,
                    qid,
                    partial: batch_out.neighbors(0).to_vec(),
                    stats: batch_out.stats(0),
                };
                if reply_tx.send(WorkerReplyMsg::Single(reply)).is_err() {
                    break; // node gone
                }
            }
            WorkerMsg::QueryBatch { qid0, qs, nq } => {
                index.query_batch(
                    engine.as_ref(),
                    &qs,
                    &shard.points,
                    &shard.labels,
                    id_base,
                    &mut scratch,
                    &mut batch_out,
                );
                debug_assert_eq!(batch_out.len(), nq);
                if send_batch_reply(&reply_tx, core, qid0, &batch_out).is_err() {
                    break;
                }
            }
            WorkerMsg::QueryBatchBudget { qid0, qs, nq, deadline_ns } => {
                let cancel = ScanCancel::until(Arc::clone(&clock), deadline_ns);
                index.query_batch_cancel(
                    engine.as_ref(),
                    &qs,
                    &shard.points,
                    &shard.labels,
                    id_base,
                    &mut scratch,
                    &mut batch_out,
                    &cancel,
                );
                debug_assert_eq!(batch_out.len(), nq);
                if send_batch_reply(&reply_tx, core, qid0, &batch_out).is_err() {
                    break;
                }
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

/// Ship one flat batch reply (shared by the plain and budget arms).
fn send_batch_reply(
    reply_tx: &Sender<WorkerReplyMsg>,
    core: usize,
    qid0: u64,
    batch_out: &BatchOutput,
) -> Result<(), std::sync::mpsc::SendError<WorkerReplyMsg>> {
    let (neighbors, offsets, stats) = batch_out.flat();
    reply_tx.send(WorkerReplyMsg::Batch(WorkerBatchReply {
        core,
        qid0,
        neighbors: neighbors.to_vec(),
        offsets: offsets.to_vec(),
        stats: stats.to_vec(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_tables_partition_exactly() {
        for (l, p) in [(120usize, 8usize), (12, 5), (7, 7), (3, 8)] {
            let mut seen = vec![false; l];
            for core in 0..p {
                for t in owned_tables(l, p, core) {
                    assert!(!seen[t], "table {t} owned twice");
                    seen[t] = true;
                }
            }
            assert!(seen.iter().all(|s| *s), "unowned tables for l={l} p={p}");
            // Balance: sizes differ by at most 1.
            let sizes: Vec<usize> = (0..p).map(|c| owned_tables(l, p, c).len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
        }
    }
}
