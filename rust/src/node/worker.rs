//! Per-core worker (paper Figure 2): one long-lived thread per simulated
//! core `P_i`, owning `O(L_out / p)` outer tables (and their inner
//! indices), a stamped visited set, and a comparison counter. The shard's
//! points live in shared memory (`Arc<Dataset>`); buckets hold local ids
//! into it.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::data::Dataset;
use crate::engine::DistanceEngine;
use crate::knn::heap::Neighbor;
use crate::slsh::{QueryStats, SlshIndex, SlshParams};
use crate::util::stamp::StampSet;

/// Messages a worker accepts.
pub enum WorkerMsg {
    /// Resolve a query; reply through the node's gather channel.
    Query { qid: u64, q: Arc<Vec<f32>> },
    /// Drain and exit.
    Shutdown,
}

/// One worker's partial answer.
pub struct WorkerReply {
    pub core: usize,
    pub qid: u64,
    pub partial: Vec<Neighbor>,
    pub stats: QueryStats,
}

/// Table indices owned by core `i` of `p`: `{t : t ≡ i (mod p)}` — the
/// paper's O(L/p)-tables-per-processor round-robin split.
pub fn owned_tables(l: usize, p: usize, core: usize) -> Vec<usize> {
    (0..l).filter(|t| t % p == core).collect()
}

/// Worker main loop: build the owned tables, then serve queries.
///
/// `ready` fires once construction finishes (the node master waits for all
/// cores before declaring the node built — table construction is entirely
/// parallel, per the paper).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    core: usize,
    shard: Arc<Dataset>,
    id_base: u64,
    params: SlshParams,
    tables: Vec<usize>,
    engine: Box<dyn DistanceEngine>,
    rx: Receiver<WorkerMsg>,
    reply_tx: Sender<WorkerReply>,
    ready: Sender<usize>,
) {
    let index = SlshIndex::build(&params, &*shard, &tables);
    let mut visited = StampSet::new(shard.len().max(1));
    let mut scratch: Vec<u32> = Vec::new();
    let _ = ready.send(core);
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Query { qid, q } => {
                let out = index.query(
                    engine.as_ref(),
                    &q,
                    &shard.points,
                    &shard.labels,
                    id_base,
                    &mut visited,
                    &mut scratch,
                );
                let reply = WorkerReply {
                    core,
                    qid,
                    partial: out.topk.into_sorted(),
                    stats: out.stats,
                };
                if reply_tx.send(reply).is_err() {
                    break; // node gone
                }
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_tables_partition_exactly() {
        for (l, p) in [(120usize, 8usize), (12, 5), (7, 7), (3, 8)] {
            let mut seen = vec![false; l];
            for core in 0..p {
                for t in owned_tables(l, p, core) {
                    assert!(!seen[t], "table {t} owned twice");
                    seen[t] = true;
                }
            }
            assert!(seen.iter().all(|s| *s), "unowned tables for l={l} p={p}");
            // Balance: sizes differ by at most 1.
            let sizes: Vec<usize> = (0..p).map(|c| owned_tables(l, p, c).len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
        }
    }
}
