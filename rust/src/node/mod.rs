//! SLSH node runtime (paper Figure 2): per-core workers owning table
//! shards over a shared-memory dataset slice, gathered by a node Master.

pub mod node;
pub mod worker;

pub use node::{InsertReply, LocalNode, NodeInfo, NodeReply};
