//! Bounded top-K accumulator — the per-core partial K-NN set.
//!
//! A fixed-capacity binary max-heap on (distance, id): the root is the
//! current worst of the best-K, so each candidate costs one compare in
//! the common reject case. Ties break on the smaller global id, making
//! every reduction in the system deterministic and partition-invariant.

/// One retrieved neighbor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Global point id.
    pub id: u64,
    /// Distance to the query (metric chosen by the caller).
    pub dist: f32,
    /// The neighbor's AHE label (carried so the Orchestrator's Reducer can
    /// vote without a second round-trip to the nodes).
    pub label: bool,
}

impl Neighbor {
    /// Total order: by distance, then id. NaN distances sort last (and are
    /// rejected on push).
    #[inline]
    pub fn before(&self, other: &Neighbor) -> bool {
        match self.dist.partial_cmp(&other.dist) {
            Some(std::cmp::Ordering::Less) => true,
            Some(std::cmp::Ordering::Greater) => false,
            _ => self.id < other.id,
        }
    }
}

/// Fixed-capacity top-K max-heap.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    heap: Vec<Neighbor>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK with k == 0");
        Self { k, heap: Vec::with_capacity(k) }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current worst retained distance (∞ while under capacity).
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].dist
        }
    }

    /// Offer a candidate; keeps the K best.
    #[inline]
    pub fn push(&mut self, n: Neighbor) {
        if n.dist.is_nan() {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(n);
            self.sift_up(self.heap.len() - 1);
        } else if n.before(&self.heap[0]) {
            self.heap[0] = n;
            self.sift_down(0);
        }
    }

    /// Insert with id-deduplication — REQUIRED when merging partial
    /// results whose candidate sets may overlap (the same point probed by
    /// several cores): a K-NN set holds distinct points. O(K) id scan;
    /// K = 10 in the paper, so this stays cheap. The raw [`push`] skips
    /// the scan and is reserved for per-core candidate scans, where the
    /// stamped visited-set already guarantees distinct ids.
    ///
    /// [`push`]: TopK::push
    #[inline]
    pub fn push_unique(&mut self, n: Neighbor) {
        if self.heap.iter().any(|m| m.id == n.id) {
            return; // same point, same distance — nothing to improve
        }
        self.push(n);
    }

    /// Merge another partial result in (the Reducer's operation).
    /// Deduplicates by id: partials from different cores/nodes may contain
    /// the same point.
    pub fn merge(&mut self, other: &TopK) {
        for &n in &other.heap {
            self.push_unique(n);
        }
    }

    /// Extract neighbors sorted ascending by (dist, id).
    pub fn into_sorted(mut self) -> Vec<Neighbor> {
        let mut out = Vec::with_capacity(self.heap.len());
        self.drain_sorted_into(&mut out);
        out
    }

    /// Reset for reuse with capacity kept — the batched query path pools
    /// `TopK`s in a scratch arena so the steady state allocates nothing.
    pub fn reset(&mut self, k: usize) {
        assert!(k > 0, "TopK with k == 0");
        self.k = k;
        self.heap.clear();
    }

    /// Append the retained neighbors to `out` in ascending (dist, id)
    /// order, then clear, keeping the heap's capacity for the next query.
    /// [`into_sorted`] is implemented on top of this, so the two can
    /// never diverge in ordering.
    ///
    /// [`into_sorted`]: TopK::into_sorted
    pub fn drain_sorted_into(&mut self, out: &mut Vec<Neighbor>) {
        self.heap.sort_by(|a, b| {
            if a.before(b) { std::cmp::Ordering::Less } else { std::cmp::Ordering::Greater }
        });
        out.extend_from_slice(&self.heap);
        self.heap.clear();
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            // Max-heap on `before`: parent must NOT be before child.
            if self.heap[parent].before(&self.heap[i]) {
                self.heap.swap(parent, i);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.heap.len() && self.heap[largest].before(&self.heap[l]) {
                largest = l;
            }
            if r < self.heap.len() && self.heap[largest].before(&self.heap[r]) {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn nb(id: u64, dist: f32) -> Neighbor {
        Neighbor { id, dist, label: id % 2 == 0 }
    }

    #[test]
    fn keeps_k_smallest() {
        let mut t = TopK::new(3);
        for (id, d) in [(0, 5.0), (1, 1.0), (2, 4.0), (3, 2.0), (4, 3.0), (5, 0.5)] {
            t.push(nb(id, d));
        }
        let out = t.into_sorted();
        let ids: Vec<u64> = out.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![5, 1, 3]);
        assert!(out.windows(2).all(|w| w[0].dist <= w[1].dist));
    }

    #[test]
    fn matches_full_sort_reference() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        for k in [1usize, 5, 10, 64] {
            let candidates: Vec<Neighbor> =
                (0..500).map(|id| nb(id, (rng.gen_below(100)) as f32)).collect();
            let mut topk = TopK::new(k);
            for &c in &candidates {
                topk.push(c);
            }
            let mut reference = candidates.clone();
            reference.sort_by(|a, b| {
                if a.before(b) { std::cmp::Ordering::Less } else { std::cmp::Ordering::Greater }
            });
            reference.truncate(k);
            assert_eq!(topk.into_sorted(), reference, "k={k}");
        }
    }

    #[test]
    fn tie_break_on_id_is_deterministic() {
        let mut t = TopK::new(2);
        for id in [9u64, 4, 7, 1] {
            t.push(nb(id, 3.0));
        }
        let ids: Vec<u64> = t.into_sorted().iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![1, 4]);
    }

    #[test]
    fn merge_equals_pushing_union() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let all: Vec<Neighbor> =
            (0..200).map(|id| nb(id, rng.next_f32() * 10.0)).collect();
        // Split into 4 "cores", each building a partial top-10.
        let mut partials: Vec<TopK> = (0..4).map(|_| TopK::new(10)).collect();
        for (i, &c) in all.iter().enumerate() {
            partials[i % 4].push(c);
        }
        let mut merged = TopK::new(10);
        for p in &partials {
            merged.merge(p);
        }
        let mut direct = TopK::new(10);
        for &c in &all {
            direct.push(c);
        }
        assert_eq!(merged.into_sorted(), direct.into_sorted());
    }

    #[test]
    fn threshold_enables_early_reject() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), f32::INFINITY);
        t.push(nb(0, 1.0));
        t.push(nb(1, 2.0));
        assert_eq!(t.threshold(), 2.0);
        t.push(nb(2, 1.5));
        assert_eq!(t.threshold(), 1.5);
    }

    #[test]
    fn nan_rejected_under_capacity() {
        let mut t = TopK::new(3);
        t.push(nb(0, f32::NAN));
        t.push(nb(1, 1.0));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn drain_sorted_matches_into_sorted_and_reuses() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut pooled = TopK::new(4);
        let mut flat: Vec<Neighbor> = Vec::new();
        for round in 0..3 {
            pooled.reset(4);
            let candidates: Vec<Neighbor> =
                (0..50).map(|id| nb(id + round * 100, rng.next_f32() * 9.0)).collect();
            let mut fresh = TopK::new(4);
            for &c in &candidates {
                pooled.push(c);
                fresh.push(c);
            }
            let start = flat.len();
            pooled.drain_sorted_into(&mut flat);
            assert_eq!(&flat[start..], fresh.into_sorted().as_slice(), "round {round}");
            assert!(pooled.is_empty());
        }
        assert_eq!(flat.len(), 12);
    }

    #[test]
    fn fewer_candidates_than_k() {
        let mut t = TopK::new(10);
        t.push(nb(1, 2.0));
        t.push(nb(0, 1.0));
        let out = t.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, 0);
    }
}
