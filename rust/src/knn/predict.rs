//! Prediction from a K-NN set: inverse-distance weighted voting with
//! K = 10, as in the paper (§4.1 "using weighted voting with K = 10
//! nearest neighbors for prediction").

use crate::knn::heap::Neighbor;

/// Weighted-voting predictor configuration.
#[derive(Debug, Clone)]
pub struct VoteConfig {
    /// Additive smoothing in the weight 1/(dist + eps); also what an exact
    /// duplicate (dist = 0) weighs against.
    pub eps: f32,
    /// Positive-class decision threshold on the weighted vote share.
    pub threshold: f32,
}

impl Default for VoteConfig {
    fn default() -> Self {
        Self { eps: 1e-3, threshold: 0.5 }
    }
}

/// Weighted vote share of the positive class in `[0, 1]`.
/// Empty neighbor sets abstain with 0 (predict negative — the majority
/// class under the paper's ≥96% imbalance).
pub fn positive_share(neighbors: &[Neighbor], cfg: &VoteConfig) -> f64 {
    if neighbors.is_empty() {
        return 0.0;
    }
    let mut pos = 0.0f64;
    let mut total = 0.0f64;
    for n in neighbors {
        let w = 1.0 / (n.dist as f64 + cfg.eps as f64);
        total += w;
        if n.label {
            pos += w;
        }
    }
    if total == 0.0 {
        0.0
    } else {
        pos / total
    }
}

/// Binary prediction by thresholded weighted vote.
pub fn predict(neighbors: &[Neighbor], cfg: &VoteConfig) -> bool {
    positive_share(neighbors, cfg) >= cfg.threshold as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nb(dist: f32, label: bool) -> Neighbor {
        Neighbor { id: 0, dist, label }
    }

    #[test]
    fn unanimous_votes() {
        let cfg = VoteConfig::default();
        let pos = vec![nb(1.0, true), nb(2.0, true)];
        let neg = vec![nb(1.0, false), nb(2.0, false)];
        assert!(predict(&pos, &cfg));
        assert!(!predict(&neg, &cfg));
        assert_eq!(positive_share(&pos, &cfg), 1.0);
        assert_eq!(positive_share(&neg, &cfg), 0.0);
    }

    #[test]
    fn closer_neighbors_dominate() {
        let cfg = VoteConfig::default();
        // One very close positive vs three distant negatives.
        let mixed = vec![nb(0.1, true), nb(10.0, false), nb(10.0, false), nb(10.0, false)];
        assert!(predict(&mixed, &cfg), "share={}", positive_share(&mixed, &cfg));
        // Inverted distances flip the call.
        let mixed2 = vec![nb(10.0, true), nb(0.1, false), nb(0.2, false), nb(0.3, false)];
        assert!(!predict(&mixed2, &cfg));
    }

    #[test]
    fn exact_duplicate_handled() {
        let cfg = VoteConfig::default();
        let v = vec![nb(0.0, true), nb(0.5, false)];
        let s = positive_share(&v, &cfg);
        assert!(s > 0.9, "duplicate should dominate: {s}");
    }

    #[test]
    fn empty_predicts_negative() {
        let cfg = VoteConfig::default();
        assert!(!predict(&[], &cfg));
    }

    #[test]
    fn threshold_is_respected() {
        let strict = VoteConfig { threshold: 0.9, ..Default::default() };
        let v = vec![nb(1.0, true), nb(1.0, false)]; // share = 0.5
        assert!(!predict(&v, &strict));
        let lax = VoteConfig { threshold: 0.4, ..Default::default() };
        assert!(predict(&v, &lax));
    }
}
