//! K-NN reduction — the Reducer's merge of per-node partial results and
//! the node Master's merge of per-core partials (paper §3): "These local
//! outputs are gathered at the Reducer, which yields the global K-NN set
//! by keeping the K closest candidates to the query."

use crate::knn::heap::{Neighbor, TopK};

/// Reduce partial K-NN lists to the global K best.
///
/// Invariant (tested): for any partition of a candidate multiset into
/// partial top-K lists, the reduction equals the top-K of the full set —
/// this is what makes predictions independent of (ν, p).
pub fn reduce_partials(partials: &[Vec<Neighbor>], k: usize) -> Vec<Neighbor> {
    let mut topk = TopK::new(k);
    for partial in partials {
        for &n in partial {
            topk.push_unique(n);
        }
    }
    topk.into_sorted()
}

/// Streaming variant used by the Reducer process: fold one node's partial
/// into an accumulator without materializing all partials first.
pub fn fold_partial(acc: &mut TopK, partial: &[Neighbor]) {
    for &n in partial {
        acc.push_unique(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_neighbors(n: usize, seed: u64) -> Vec<Neighbor> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n as u64)
            .map(|id| Neighbor { id, dist: rng.next_f32() * 50.0, label: rng.gen_bool(0.2) })
            .collect()
    }

    fn topk_of(all: &[Neighbor], k: usize) -> Vec<Neighbor> {
        let mut t = TopK::new(k);
        for &n in all {
            t.push(n);
        }
        t.into_sorted()
    }

    #[test]
    fn reduction_equals_global_topk_for_any_partition() {
        let all = random_neighbors(1000, 1);
        let global = topk_of(&all, 10);
        let mut rng = Xoshiro256::seed_from_u64(2);
        for parts in [1usize, 2, 5, 40] {
            // Random assignment of candidates to parts, each part keeps
            // its own top-10 (as cores/nodes do).
            let mut buckets: Vec<Vec<Neighbor>> = vec![Vec::new(); parts];
            for &n in &all {
                buckets[rng.gen_index(parts)].push(n);
            }
            let partials: Vec<Vec<Neighbor>> =
                buckets.iter().map(|b| topk_of(b, 10)).collect();
            assert_eq!(reduce_partials(&partials, 10), global, "parts={parts}");
        }
    }

    #[test]
    fn fold_matches_batch_reduce() {
        let partials: Vec<Vec<Neighbor>> =
            (0..6).map(|s| topk_of(&random_neighbors(100, s), 5)).collect();
        let batch = reduce_partials(&partials, 5);
        let mut acc = TopK::new(5);
        for p in &partials {
            fold_partial(&mut acc, p);
        }
        assert_eq!(acc.into_sorted(), batch);
    }

    #[test]
    fn reduce_with_fewer_than_k() {
        // Disjoint id ranges (distinct global points).
        let mut a = random_neighbors(2, 3);
        let mut b = random_neighbors(1, 4);
        for n in &mut b {
            n.id += 100;
        }
        a.truncate(2);
        let partials = vec![a, b];
        let out = reduce_partials(&partials, 10);
        assert_eq!(out.len(), 3);
        assert!(out.windows(2).all(|w| w[0].before(&w[1])));
    }

    #[test]
    fn duplicate_ids_across_partials_dedup() {
        // The same global point found by two cores must appear once.
        let shared = Neighbor { id: 7, dist: 1.5, label: true };
        let partials = vec![
            vec![shared, Neighbor { id: 1, dist: 3.0, label: false }],
            vec![shared, Neighbor { id: 2, dist: 2.0, label: false }],
        ];
        let out = reduce_partials(&partials, 10);
        assert_eq!(out.len(), 3);
        assert_eq!(out.iter().filter(|n| n.id == 7).count(), 1);
    }

    #[test]
    fn empty_reduction() {
        assert!(reduce_partials(&[], 5).is_empty());
        assert!(reduce_partials(&[vec![], vec![]], 5).is_empty());
    }
}
