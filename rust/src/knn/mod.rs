//! K-nearest-neighbor machinery: bounded top-K, the PKNN exhaustive
//! baseline, weighted-voting prediction, and partial-result reduction.

pub mod exhaustive;
pub mod heap;
pub mod predict;
pub mod reduce;

pub use exhaustive::{pknn_query, pknn_query_batch, PknnResult};
pub use heap::{Neighbor, TopK};
pub use predict::{positive_share, predict, VoteConfig};
pub use reduce::{fold_partial, reduce_partials};
