//! Exhaustive K-NN — the paper's PKNN baseline.
//!
//! Data-parallel exhaustive search "assigns equal shares of the points to
//! all the processors in all the nodes, resulting in n/(pν) comparisons
//! per processor" (paper §4.1). [`pknn_query`] simulates exactly that:
//! the shard is split into `procs` equal ranges, each scanned into a
//! partial top-K, and the partials reduced — returning both the answer
//! and the per-processor comparison counts the tables report.

use crate::engine::{DistanceEngine, Metric};
use crate::knn::heap::{Neighbor, TopK};
use crate::util::threadpool::chunk_ranges;

/// Result of one exhaustive query.
#[derive(Debug, Clone)]
pub struct PknnResult {
    pub neighbors: Vec<Neighbor>,
    /// Comparisons performed by each (simulated) processor.
    pub comparisons: Vec<u64>,
}

/// Exhaustive K-NN over `data` split across `procs` equal shares.
#[allow(clippy::too_many_arguments)]
pub fn pknn_query(
    engine: &dyn DistanceEngine,
    metric: Metric,
    q: &[f32],
    data: &[f32],
    dim: usize,
    labels: &[bool],
    k: usize,
    procs: usize,
) -> PknnResult {
    let n = labels.len();
    debug_assert_eq!(data.len(), n * dim);
    let mut comparisons = Vec::with_capacity(procs);
    let mut global = TopK::new(k);
    for range in chunk_ranges(n, procs) {
        let mut partial = TopK::new(k);
        let c = engine.scan_range(
            metric,
            q,
            data,
            dim,
            range.start as u32..range.end as u32,
            labels,
            0,
            &mut partial,
        );
        comparisons.push(c);
        global.merge(&partial);
    }
    PknnResult { neighbors: global.into_sorted(), comparisons }
}

/// Batched exhaustive K-NN: resolve a block of queries (`qs` row-major
/// `nq × dim`) against the same `procs`-way partitioning. Rides the
/// engine's register-blocked [`scan_batch_range`] so every data row is
/// fetched once per query tile instead of once per query — results are
/// bit-identical to calling [`pknn_query`] once per row.
///
/// [`scan_batch_range`]: crate::engine::DistanceEngine::scan_batch_range
#[allow(clippy::too_many_arguments)]
pub fn pknn_query_batch(
    engine: &dyn DistanceEngine,
    metric: Metric,
    qs: &[f32],
    data: &[f32],
    dim: usize,
    labels: &[bool],
    k: usize,
    procs: usize,
) -> Vec<PknnResult> {
    assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
    let nq = qs.len() / dim;
    let n = labels.len();
    debug_assert_eq!(data.len(), n * dim);
    let mut globals: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
    let mut comparisons: Vec<Vec<u64>> = (0..nq).map(|_| Vec::with_capacity(procs)).collect();
    let mut partials: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
    for range in chunk_ranges(n, procs) {
        for p in partials.iter_mut() {
            p.reset(k);
        }
        let share = range.len() as u64;
        let total = engine.scan_batch_range(
            metric,
            qs,
            data,
            dim,
            range.start as u32..range.end as u32,
            labels,
            0,
            &mut partials,
        );
        debug_assert_eq!(total, share * nq as u64);
        for qi in 0..nq {
            comparisons[qi].push(share);
            globals[qi].merge(&partials[qi]);
        }
    }
    globals
        .into_iter()
        .zip(comparisons)
        .map(|(g, c)| PknnResult { neighbors: g.into_sorted(), comparisons: c })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeEngine;
    use crate::engine::l1_dist;
    use crate::util::rng::Xoshiro256;

    fn fixture(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>, Vec<f32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let data = (0..n * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        let labels = (0..n).map(|_| rng.gen_bool(0.1)).collect();
        let q = (0..dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
        (data, labels, q)
    }

    #[test]
    fn comparisons_are_equal_shares() {
        let (data, labels, q) = fixture(1000, 30, 1);
        let engine = NativeEngine::new();
        for procs in [1usize, 3, 8, 40] {
            let r = pknn_query(&engine, Metric::L1, &q, &data, 30, &labels, 10, procs);
            assert_eq!(r.comparisons.len(), procs);
            assert_eq!(r.comparisons.iter().sum::<u64>(), 1000);
            let max = *r.comparisons.iter().max().unwrap();
            let min = *r.comparisons.iter().min().unwrap();
            assert!(max - min <= 1, "shares not equal: {:?}", r.comparisons);
            assert_eq!(max, (1000usize.div_ceil(procs)) as u64);
        }
    }

    #[test]
    fn result_invariant_to_processor_count() {
        let (data, labels, q) = fixture(500, 30, 2);
        let engine = NativeEngine::new();
        let base = pknn_query(&engine, Metric::L1, &q, &data, 30, &labels, 7, 1);
        for procs in [2usize, 5, 16] {
            let r = pknn_query(&engine, Metric::L1, &q, &data, 30, &labels, 7, procs);
            assert_eq!(r.neighbors, base.neighbors, "procs={procs}");
        }
    }

    #[test]
    fn batch_is_bit_identical_to_sequential() {
        let dim = 30;
        let (data, labels, _) = fixture(700, dim, 5);
        let engine = NativeEngine::new();
        let mut rng = Xoshiro256::seed_from_u64(6);
        for metric in [Metric::L1, Metric::Cosine] {
            for procs in [1usize, 3, 8] {
                for nq in [1usize, 4, 6] {
                    let qs: Vec<f32> =
                        (0..nq * dim).map(|_| rng.gen_f64(0.0, 100.0) as f32).collect();
                    let batch =
                        pknn_query_batch(&engine, metric, &qs, &data, dim, &labels, 10, procs);
                    assert_eq!(batch.len(), nq);
                    for qi in 0..nq {
                        let seq = pknn_query(
                            &engine,
                            metric,
                            &qs[qi * dim..(qi + 1) * dim],
                            &data,
                            dim,
                            &labels,
                            10,
                            procs,
                        );
                        assert_eq!(batch[qi].neighbors, seq.neighbors, "{metric:?} procs={procs} qi={qi}");
                        assert_eq!(batch[qi].comparisons, seq.comparisons);
                    }
                }
            }
        }
    }

    #[test]
    fn finds_true_nearest() {
        let (mut data, labels, q) = fixture(300, 30, 3);
        // Plant an exact duplicate of the query at row 123.
        data[123 * 30..124 * 30].copy_from_slice(&q);
        let engine = NativeEngine::new();
        let r = pknn_query(&engine, Metric::L1, &q, &data, 30, &labels, 3, 4);
        assert_eq!(r.neighbors[0].id, 123);
        assert_eq!(r.neighbors[0].dist, 0.0);
        // Full-sort cross-check for rank 2.
        let mut all: Vec<(f32, u64)> = (0..300)
            .map(|i| (l1_dist(&q, &data[i * 30..(i + 1) * 30]), i as u64))
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(r.neighbors[1].id, all[1].1);
    }
}
