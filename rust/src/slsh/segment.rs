//! Segment primitives for the live (streaming) SLSH index.
//!
//! A [`LiveIndex`](crate::slsh::live::LiveIndex) is a stack of sealed,
//! immutable segments plus one append-only **delta** segment. This module
//! holds the pieces a segment is made of, all built around a single
//! publication discipline — *epoch-guarded snapshot reads*:
//!
//! * [`AppendBuf`] — a fixed-capacity, single-writer, multi-reader append
//!   buffer. The writer fills slots past the published prefix; readers
//!   only ever dereference the prefix an `Acquire` counter told them is
//!   complete, so a query racing an insert can never observe torn floats.
//! * [`Extent`] — one contiguous block of points (rows × dim + labels)
//!   shared by every core of a node. Row count is published with a single
//!   `Release` store *after* the row data is fully written.
//! * [`DeltaTable`] — a growable open-addressing hash table supporting
//!   hash-on-insert while concurrent readers probe it. Bucket membership
//!   is a forward-linked chain in insertion order (ids strictly
//!   ascending), so a reader walking under epoch `e` stops at the first
//!   entry `≥ e` and sees exactly the prefix of the bucket that existed
//!   at its snapshot — the same bucket order `TableBuilder::freeze`
//!   produces, which is what makes a pre-seal delta bit-compatible with
//!   the batch-built index in LSH-only mode.
//! * [`DeltaSegment`] — one owner's (core's) delta: hash-on-insert outer
//!   tables over the current extent. No inner (stratified) indices live
//!   here; those are built at seal time, when the bucket populations are
//!   final.
//! * [`SealedSegment`] — a frozen delta: a regular [`SlshIndex`] (inner
//!   indices included) built over the extent's final rows. Sealing an
//!   extent that grew from empty yields an index bit-identical to
//!   [`SlshIndex::build`] over the same points — the seal-equivalence
//!   contract `rust/tests/streaming_ingest.rs` pins.
//!
//! Segment scans run on the caller's [`DistanceEngine`]; because the
//! engine's SIMD kernels are bit-identical to its scalar path (see
//! [`crate::engine::ScanKernel`]), the delta's epoch-prefix answers and
//! the seal-equivalence contract are kernel-independent.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU32, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::engine::{DistanceEngine, Metric, ScanCancel};
use crate::lsh::family::{ComposedHash, LayerSpec};
use crate::lsh::key::PackedKey;
use crate::lsh::layer::SliceView;
use crate::lsh::probe::ProbeSpec;
use crate::slsh::index::{BatchOutput, QueryScratch, QueryStats, SlshIndex};
use crate::slsh::params::SlshParams;
use crate::util::stamp::StampSet;

/// Why an extent was closed (and hence a segment sealed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SealReason {
    /// The extent reached the policy's `max_points`.
    Size,
    /// The extent's first point aged past the policy's `max_age`.
    Age,
    /// An explicit `seal_now` call.
    Forced,
}

impl SealReason {
    fn as_u8(self) -> u8 {
        match self {
            SealReason::Size => 1,
            SealReason::Age => 2,
            SealReason::Forced => 3,
        }
    }

    fn from_u8(v: u8) -> Option<SealReason> {
        match v {
            1 => Some(SealReason::Size),
            2 => Some(SealReason::Age),
            3 => Some(SealReason::Forced),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// AppendBuf — fixed-capacity single-writer publish buffer
// ---------------------------------------------------------------------------

/// Fixed-capacity append buffer: one writer fills slots, readers see a
/// stable `&[T]` prefix. The buffer itself carries NO length — publication
/// is the owner's job (one `Release` counter covering data and labels
/// together), which keeps the unsafe surface to two small functions.
struct AppendBuf<T> {
    cells: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

// SAFETY: access follows the single-writer/prefix-reader protocol below —
// the writer only touches cells at indices ≥ every published prefix, and
// readers only dereference cells < a prefix length they obtained through
// an Acquire load that synchronizes with the writer's Release publish.
// The two regions are disjoint, so no cell is ever read and written
// concurrently.
unsafe impl<T: Send + Sync> Sync for AppendBuf<T> {}
unsafe impl<T: Send> Send for AppendBuf<T> {}

impl<T: Copy> AppendBuf<T> {
    fn new(cap: usize) -> AppendBuf<T> {
        let cells: Box<[UnsafeCell<MaybeUninit<T>>]> =
            (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        AppendBuf { cells }
    }

    /// Write `xs` starting at slot `at`.
    ///
    /// SAFETY: caller must be the single writer, `at + xs.len()` must be
    /// within capacity, and `[at, at + xs.len())` must lie entirely past
    /// every published prefix.
    unsafe fn write(&self, at: usize, xs: &[T]) {
        debug_assert!(at + xs.len() <= self.cells.len());
        for (i, &x) in xs.iter().enumerate() {
            (*self.cells[at + i].get()).write(x);
        }
    }

    /// The initialized prefix of length `n`.
    ///
    /// SAFETY: `n` must not exceed a prefix length obtained via an
    /// Acquire load that observed the writer's Release publish of at
    /// least `n` initialized slots.
    unsafe fn prefix(&self, n: usize) -> &[T] {
        debug_assert!(n <= self.cells.len());
        // UnsafeCell<MaybeUninit<T>> has the same layout as T.
        std::slice::from_raw_parts(self.cells.as_ptr() as *const T, n)
    }
}

// ---------------------------------------------------------------------------
// Extent — one contiguous block of live points
// ---------------------------------------------------------------------------

/// One contiguous, fixed-capacity block of points in a node's live store.
/// Extents never move or reallocate, so every segment's scan kernel gets
/// the flat `&[f32]` slice it wants; the row count is the publication
/// point (`Release` after the row's floats and label are written).
pub struct Extent {
    dim: usize,
    cap: usize,
    /// Store-global index of row 0 (global id = node `id_base` + this +
    /// local row).
    start: u64,
    /// Clock reading at creation — the age-seal origin.
    created_ns: u64,
    data: AppendBuf<f32>,
    labels: AppendBuf<bool>,
    rows: AtomicUsize,
    /// 0 while open, else a [`SealReason`] discriminant (`Release` after
    /// the final row publish, so a reader that observes "closed" also
    /// observes the final row count).
    closed: AtomicU8,
}

impl Extent {
    pub(crate) fn new(dim: usize, cap: usize, start: u64, created_ns: u64) -> Extent {
        assert!(dim > 0 && cap > 0, "extent needs dim > 0 and cap > 0");
        Extent {
            dim,
            cap,
            start,
            created_ns,
            data: AppendBuf::new(cap * dim),
            labels: AppendBuf::new(cap),
            rows: AtomicUsize::new(0),
            closed: AtomicU8::new(0),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn start(&self) -> u64 {
        self.start
    }

    pub(crate) fn created_ns(&self) -> u64 {
        self.created_ns
    }

    /// Rows fully written and visible to readers.
    pub fn published_rows(&self) -> usize {
        self.rows.load(Ordering::Acquire)
    }

    /// Writer-side row count (callers must hold the store's write lock).
    pub(crate) fn writer_rows(&self) -> usize {
        self.rows.load(Ordering::Relaxed)
    }

    /// Append `lbs.len()` rows. Single writer (the store's write lock).
    pub(crate) fn append(&self, pts: &[f32], lbs: &[bool]) {
        let n = lbs.len();
        let r = self.writer_rows();
        assert_eq!(pts.len(), n * self.dim, "row block not n × dim");
        assert!(r + n <= self.cap, "extent overflow");
        // SAFETY: single writer; the target slots are past the published
        // prefix (published ≤ writer rows) and within capacity.
        unsafe {
            self.data.write(r * self.dim, pts);
            self.labels.write(r, lbs);
        }
        self.rows.store(r + n, Ordering::Release);
    }

    pub(crate) fn close(&self, reason: SealReason) {
        self.closed.store(reason.as_u8(), Ordering::Release);
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire) != 0
    }

    pub fn close_reason(&self) -> Option<SealReason> {
        SealReason::from_u8(self.closed.load(Ordering::Acquire))
    }

    /// Flat point data of the first `rows` published rows.
    pub fn data(&self, rows: usize) -> &[f32] {
        assert!(rows <= self.published_rows(), "reading past the published prefix");
        // SAFETY: `rows` is bounded by the Acquire-published row count,
        // whose Release publish happened after those rows were written.
        unsafe { self.data.prefix(rows * self.dim) }
    }

    /// Labels of the first `rows` published rows.
    pub fn labels(&self, rows: usize) -> &[bool] {
        assert!(rows <= self.published_rows(), "reading past the published prefix");
        // SAFETY: same argument as [`Extent::data`].
        unsafe { self.labels.prefix(rows) }
    }

    /// One published row.
    pub fn row(&self, i: usize) -> &[f32] {
        assert!(i < self.published_rows(), "row {i} not published");
        let d = self.data(i + 1);
        &d[i * self.dim..(i + 1) * self.dim]
    }
}

// ---------------------------------------------------------------------------
// DeltaTable — hash-on-insert table with concurrent probes
// ---------------------------------------------------------------------------

const NIL: u32 = u32::MAX;

/// Open-addressing hash table that accepts inserts from a single writer
/// while readers probe concurrently. Layout mirrors
/// [`TableBuilder`](crate::lsh::table::TableBuilder) — slots map a key to
/// a bucket, buckets are intrusive chains through a `next[]` array — but
/// the chain links FORWARD (head = oldest, append at tail), so a probe
/// yields ids in insertion order without the freeze-time reversal, and
/// since local ids are inserted in ascending order a reader can stop at
/// the first id `≥` its epoch: everything after is newer than its
/// snapshot.
///
/// Publication protocol (single writer):
/// * new bucket — write the slot's key and the bucket head, then
///   `Release`-store the slot's bucket index; a reader's `Acquire` load of
///   the slot therefore sees both.
/// * existing bucket — `Release`-store `next[tail] = id`; a reader's
///   `Acquire` chain walk sees every link published before it started.
///
/// Capacity is fixed at construction (one slot array sized for the
/// extent's `max_points`), so nothing ever reallocates under a reader.
pub struct DeltaTable {
    mask: usize,
    /// `NIL` or bucket index; the slot's publication point.
    slot_bucket: Vec<AtomicU32>,
    slot_key: Vec<UnsafeCell<MaybeUninit<PackedKey>>>,
    /// Bucket → first inserted id (written before the slot publish).
    heads: Vec<AtomicU32>,
    /// Bucket → last inserted id. Writer-only.
    tails: Vec<AtomicU32>,
    /// `next[id]` → the next id in the same bucket, `NIL` at the chain
    /// end. Pre-initialized to `NIL` for every possible id.
    next: Vec<AtomicU32>,
    /// Buckets created so far. Writer-only.
    buckets: AtomicU32,
}

// SAFETY: `slot_key[s]` is written exactly once, by the single writer,
// before the matching `slot_bucket[s]` Release store; readers only read it
// after an Acquire load of `slot_bucket[s]` returned non-NIL. All other
// shared state is atomic.
unsafe impl Sync for DeltaTable {}
unsafe impl Send for DeltaTable {}

impl DeltaTable {
    /// `cap` = maximum number of inserts (the extent's `max_points`);
    /// sized for a ≤ 0.5 load factor like the frozen table builder.
    pub fn with_capacity(cap: usize) -> DeltaTable {
        let slots = (cap.max(8) * 2).next_power_of_two();
        DeltaTable {
            mask: slots - 1,
            slot_bucket: (0..slots).map(|_| AtomicU32::new(NIL)).collect(),
            slot_key: (0..slots).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
            heads: (0..cap).map(|_| AtomicU32::new(NIL)).collect(),
            tails: (0..cap).map(|_| AtomicU32::new(NIL)).collect(),
            next: (0..cap).map(|_| AtomicU32::new(NIL)).collect(),
            buckets: AtomicU32::new(0),
        }
    }

    /// Insert local id `id` under `key`. Ids MUST arrive in strictly
    /// ascending order (the epoch-walk contract).
    ///
    /// SAFETY: caller must be the single writer (serialized externally —
    /// the live index's writer lock); concurrent inserts would race on
    /// slot claims and key cells.
    pub(crate) unsafe fn insert(&self, key: PackedKey, id: u32) {
        let mut slot = (key.digest() as usize) & self.mask;
        loop {
            let b = self.slot_bucket[slot].load(Ordering::Acquire);
            if b == NIL {
                // New bucket: head + key first, slot publish last.
                let b = self.buckets.load(Ordering::Relaxed);
                self.buckets.store(b + 1, Ordering::Relaxed);
                self.heads[b as usize].store(id, Ordering::Relaxed);
                self.tails[b as usize].store(id, Ordering::Relaxed);
                (*self.slot_key[slot].get()).write(key);
                self.slot_bucket[slot].store(b, Ordering::Release);
                return;
            }
            // SAFETY: published slot ⇒ key initialized (protocol above).
            let k = (*self.slot_key[slot].get()).assume_init_ref();
            if *k == key {
                let t = self.tails[b as usize].load(Ordering::Relaxed);
                self.next[t as usize].store(id, Ordering::Release);
                self.tails[b as usize].store(id, Ordering::Relaxed);
                return;
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// Bucket index for `key`, if any writer published one.
    pub fn find_bucket(&self, key: &PackedKey) -> Option<usize> {
        let mut slot = (key.digest() as usize) & self.mask;
        loop {
            let b = self.slot_bucket[slot].load(Ordering::Acquire);
            if b == NIL {
                return None;
            }
            // SAFETY: published slot ⇒ key initialized before the Release
            // store the Acquire load above synchronized with.
            let k = unsafe { (*self.slot_key[slot].get()).assume_init_ref() };
            if *k == *key {
                return Some(b as usize);
            }
            slot = (slot + 1) & self.mask;
        }
    }

    /// Walk bucket `b` in insertion order, visiting only ids `< epoch`;
    /// returns how many were visited. Ids are ascending, so the walk stops
    /// at the first id past the epoch — everything later is newer than the
    /// caller's snapshot.
    pub fn walk(&self, b: usize, epoch: u32, mut visit: impl FnMut(u32)) -> usize {
        let mut cur = self.heads[b].load(Ordering::Acquire);
        let mut seen = 0usize;
        while cur != NIL && cur < epoch {
            visit(cur);
            seen += 1;
            cur = self.next[cur as usize].load(Ordering::Acquire);
        }
        seen
    }
}

// ---------------------------------------------------------------------------
// DeltaSegment — one owner's hash-on-insert view of the open extent
// ---------------------------------------------------------------------------

struct DeltaTableEntry {
    hash: Box<dyn ComposedHash>,
    table: DeltaTable,
}

/// The append-only delta of one live index: the owned outer tables,
/// hash-on-insert, over the node's currently open [`Extent`]. Queries see
/// the `indexed` epoch — points are searchable only once their owner has
/// hashed them into every owned table, never partially.
pub struct DeltaSegment {
    extent: Arc<Extent>,
    /// Which store extent this delta indexes (for catch-up bookkeeping).
    extent_idx: usize,
    tables: Vec<DeltaTableEntry>,
    /// Local rows fully indexed across ALL owned tables (`Release` after
    /// the last table insert — the delta's query epoch).
    indexed: AtomicUsize,
}

impl DeltaSegment {
    pub(crate) fn new(
        outer: &LayerSpec,
        table_indices: &[usize],
        extent: Arc<Extent>,
        extent_idx: usize,
    ) -> DeltaSegment {
        let cap = extent.capacity();
        let tables = table_indices
            .iter()
            .map(|&t| DeltaTableEntry {
                hash: outer.instantiate(t),
                table: DeltaTable::with_capacity(cap),
            })
            .collect();
        DeltaSegment { extent, extent_idx, tables, indexed: AtomicUsize::new(0) }
    }

    pub(crate) fn extent_idx(&self) -> usize {
        self.extent_idx
    }

    /// Local rows visible to queries.
    pub fn indexed(&self) -> usize {
        self.indexed.load(Ordering::Acquire)
    }

    /// Store-global index of local row 0.
    pub fn start(&self) -> u64 {
        self.extent.start()
    }

    /// Catch the tables up with the extent: hash rows `[indexed, upto)`
    /// into every owned table, then publish the new epoch. Single writer
    /// (the live index's writer lock); `upto` must not exceed the
    /// extent's published rows.
    pub(crate) fn index_rows(&self, upto: usize) {
        let from = self.indexed.load(Ordering::Relaxed);
        if upto <= from {
            return;
        }
        let dim = self.extent.dim();
        let data = self.extent.data(upto);
        for i in from..upto {
            let x = &data[i * dim..(i + 1) * dim];
            for e in &self.tables {
                // SAFETY: single writer (caller holds the live index's
                // writer lock); ids arrive in ascending order.
                unsafe { e.table.insert(e.hash.hash(x), i as u32) };
            }
        }
        self.indexed.store(upto, Ordering::Release);
    }

    /// Gather one owned table's deduplicated contribution to `out` for
    /// query `q` at `epoch` — the delta twin of `SlshIndex::gather_table`
    /// (no inner indices: those exist only after sealing).
    fn gather_table(
        &self,
        pos: usize,
        q: &[f32],
        epoch: u32,
        visited: &mut StampSet,
        out: &mut Vec<u32>,
        stats: &mut QueryStats,
    ) {
        let key = self.tables[pos].hash.hash(q);
        self.gather_bucket(pos, key, epoch, visited, out, stats);
    }

    /// Gather the bucket addressed by an explicit `key` — the probe-level
    /// body multi-probe fans out over (the base key plus its flip-≤2
    /// perturbations all land here).
    fn gather_bucket(
        &self,
        pos: usize,
        key: PackedKey,
        epoch: u32,
        visited: &mut StampSet,
        out: &mut Vec<u32>,
        stats: &mut QueryStats,
    ) {
        let e = &self.tables[pos];
        let Some(b) = e.table.find_bucket(&key) else { return };
        let seen = e.table.walk(b, epoch, |id| {
            if visited.insert(id) {
                out.push(id);
            }
        });
        if seen > 0 {
            stats.direct_buckets += 1;
        }
    }

    /// Resolve a block of queries against the delta at its current epoch
    /// — the streaming twin of [`SlshIndex::query_batch`], minus inner
    /// indices. `out` is cleared and refilled with one resolved query per
    /// input row (same contract as the `SlshIndex` batch paths), reusing
    /// `scratch`.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn query_batch(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        k: usize,
        id_base: u64,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
    ) {
        self.query_batch_inner(engine, qs, k, id_base, scratch, out, None);
    }

    /// Budget-enforced twin of [`query_batch`](DeltaSegment::query_batch):
    /// table-at-a-time with the deadline checked between tables and
    /// between candidate tiles, same prefix contract as
    /// [`SlshIndex::query_batch_cancel`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn query_batch_cancel(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        k: usize,
        id_base: u64,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
        cancel: &ScanCancel,
    ) {
        self.query_batch_inner(engine, qs, k, id_base, scratch, out, Some(cancel));
    }

    /// Knob-carrying twin of the batch paths: multi-probe fan-out plus
    /// the `max_comparisons` candidate budget, optionally
    /// deadline-bounded. The baseline spec dispatches to the *exact*
    /// legacy body, mirroring [`SlshIndex::query_batch_spec`]'s contract.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn query_batch_spec(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        k: usize,
        id_base: u64,
        spec: ProbeSpec,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
        cancel: Option<&ScanCancel>,
    ) {
        if spec.is_baseline() {
            self.query_batch_inner(engine, qs, k, id_base, scratch, out, cancel);
        } else {
            self.query_batch_multi(engine, qs, k, id_base, spec, scratch, out, cancel);
        }
    }

    /// Multi-probe / capped resolution body. Identical structure to
    /// [`query_batch_inner`](DeltaSegment::query_batch_inner), except each
    /// table gathers the first `spec.probes` buckets of the query's
    /// margin-ordered probe sequence, and `spec.max_comparisons > 0`
    /// truncates the candidate walk at exactly that many comparisons
    /// (clock-free, bit-reproducible — see `SlshIndex::query_batch_spec`).
    #[allow(clippy::too_many_arguments)]
    fn query_batch_multi(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        k: usize,
        id_base: u64,
        spec: ProbeSpec,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
        cancel: Option<&ScanCancel>,
    ) {
        let dim = self.extent.dim();
        assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
        let nq = qs.len() / dim;
        let epoch = self.indexed();
        scratch.ensure(epoch.max(1), nq, k);
        out.clear();
        let data = self.extent.data(epoch);
        let labels = self.extent.labels(epoch);
        let gid_base = id_base + self.extent.start();
        let QueryScratch { visited, cand, topks, margins, probe_keys, probe, .. } = scratch;
        for qi in 0..nq {
            let q = &qs[qi * dim..(qi + 1) * dim];
            let topk = &mut topks[qi];
            topk.reset(k);
            let mut stats = QueryStats::default();
            visited.clear();
            cand.clear();
            for pos in 0..self.tables.len() {
                if let Some(c) = cancel {
                    if c.blown() {
                        stats.partial = true;
                        break;
                    }
                }
                let start = cand.len();
                let e = &self.tables[pos];
                if spec.probes > 1 {
                    let base = e.hash.hash(q);
                    e.hash.margins(q, margins);
                    probe.generate(base, margins, spec.probes, probe_keys);
                    for &key in probe_keys.iter() {
                        self.gather_bucket(pos, key, epoch as u32, visited, cand, &mut stats);
                    }
                } else {
                    self.gather_table(pos, q, epoch as u32, visited, cand, &mut stats);
                }
                stats.tables += 1;
                let mut fresh = (cand.len() - start) as u64;
                let mut capped = false;
                if spec.max_comparisons > 0 {
                    let room = spec.max_comparisons.saturating_sub(stats.comparisons);
                    if fresh > room {
                        cand.truncate(start + room as usize);
                        fresh = room;
                        capped = true;
                    }
                }
                let scanned = match cancel {
                    None => engine.scan(
                        Metric::L1,
                        q,
                        data,
                        dim,
                        &cand[start..],
                        labels,
                        gid_base,
                        topk,
                    ),
                    Some(c) => engine.scan_until(
                        Metric::L1,
                        q,
                        data,
                        dim,
                        &cand[start..],
                        labels,
                        gid_base,
                        topk,
                        c,
                    ),
                };
                stats.comparisons += scanned;
                if scanned < fresh || capped {
                    stats.partial = true;
                    break;
                }
            }
            out.push_query(topk, stats);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn query_batch_inner(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        k: usize,
        id_base: u64,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
        cancel: Option<&ScanCancel>,
    ) {
        let dim = self.extent.dim();
        assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
        let nq = qs.len() / dim;
        // The epoch is read ONCE per batch: every query in the block sees
        // the same point-set prefix.
        let epoch = self.indexed();
        scratch.ensure(epoch.max(1), nq, k);
        out.clear();
        let data = self.extent.data(epoch);
        let labels = self.extent.labels(epoch);
        let gid_base = id_base + self.extent.start();
        let QueryScratch { visited, cand, topks, .. } = scratch;
        for qi in 0..nq {
            let q = &qs[qi * dim..(qi + 1) * dim];
            let topk = &mut topks[qi];
            topk.reset(k);
            let mut stats = QueryStats::default();
            visited.clear();
            cand.clear();
            for pos in 0..self.tables.len() {
                if let Some(c) = cancel {
                    if c.blown() {
                        stats.partial = true;
                        break;
                    }
                }
                let start = cand.len();
                self.gather_table(pos, q, epoch as u32, visited, cand, &mut stats);
                stats.tables += 1;
                let fresh = (cand.len() - start) as u64;
                let scanned = match cancel {
                    None => engine.scan(
                        Metric::L1,
                        q,
                        data,
                        dim,
                        &cand[start..],
                        labels,
                        gid_base,
                        topk,
                    ),
                    Some(c) => engine.scan_until(
                        Metric::L1,
                        q,
                        data,
                        dim,
                        &cand[start..],
                        labels,
                        gid_base,
                        topk,
                        c,
                    ),
                };
                stats.comparisons += scanned;
                if scanned < fresh {
                    stats.partial = true;
                    break;
                }
            }
            out.push_query(topk, stats);
        }
    }
}

// ---------------------------------------------------------------------------
// SealedSegment — a frozen delta
// ---------------------------------------------------------------------------

/// An immutable segment of a live index: a regular [`SlshIndex`] (inner
/// stratified indices included, built now that bucket populations are
/// final) over a closed extent's rows. Local ids are extent-relative;
/// global ids are `id_base + start + local`.
pub struct SealedSegment {
    pub index: SlshIndex,
    extent: Arc<Extent>,
    rows: usize,
}

impl SealedSegment {
    /// Build the owned tables over the extent's final `rows` — exactly
    /// [`SlshIndex::build`] over those points, which is the
    /// seal-equivalence contract.
    pub(crate) fn build(
        params: &SlshParams,
        table_indices: &[usize],
        extent: Arc<Extent>,
        rows: usize,
    ) -> SealedSegment {
        let view = SliceView { data: extent.data(rows), dim: extent.dim() };
        let index = SlshIndex::build(params, &view, table_indices);
        SealedSegment { index, extent, rows }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn start(&self) -> u64 {
        self.extent.start()
    }

    pub fn data(&self) -> &[f32] {
        self.extent.data(self.rows)
    }

    pub fn labels(&self) -> &[bool] {
        self.extent.labels(self.rows)
    }

    pub fn close_reason(&self) -> Option<SealReason> {
        self.extent.close_reason()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    use std::collections::BTreeMap;

    fn key_of(v: u64) -> PackedKey {
        PackedKey::from_bits((0..64).map(|b| (v >> b) & 1 == 1))
    }

    #[test]
    fn extent_publishes_rows_after_data() {
        let e = Extent::new(3, 10, 100, 7);
        assert_eq!(e.published_rows(), 0);
        e.append(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[true, false]);
        assert_eq!(e.published_rows(), 2);
        assert_eq!(e.data(2), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(e.labels(2), &[true, false]);
        assert_eq!(e.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(e.start(), 100);
        assert!(!e.is_closed());
        e.close(SealReason::Age);
        assert_eq!(e.close_reason(), Some(SealReason::Age));
    }

    #[test]
    #[should_panic(expected = "extent overflow")]
    fn extent_rejects_overflow() {
        let e = Extent::new(2, 1, 0, 0);
        e.append(&[0.0, 0.0, 1.0, 1.0], &[false, false]);
    }

    #[test]
    fn delta_table_grouping_matches_btreemap_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let n = 5000usize;
        let table = DeltaTable::with_capacity(n);
        let mut reference: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for id in 0..n as u32 {
            let v = rng.gen_below(200); // heavy collisions
            // SAFETY: single-threaded test = single writer.
            unsafe { table.insert(key_of(v), id) };
            reference.entry(v).or_default().push(id);
        }
        for (&v, ids) in &reference {
            let b = table.find_bucket(&key_of(v)).expect("bucket must exist");
            let mut got = Vec::new();
            let seen = table.walk(b, n as u32, |id| got.push(id));
            assert_eq!(seen, ids.len());
            assert_eq!(&got, ids, "bucket for {v} (insertion order)");
        }
        assert!(table.find_bucket(&key_of(9999)).is_none());
    }

    #[test]
    fn delta_table_walk_respects_epoch() {
        let table = DeltaTable::with_capacity(16);
        for id in 0..8u32 {
            // SAFETY: single writer.
            unsafe { table.insert(key_of(5), id) };
        }
        let b = table.find_bucket(&key_of(5)).unwrap();
        for epoch in [0u32, 1, 3, 8, 100] {
            let mut got = Vec::new();
            table.walk(b, epoch, |id| got.push(id));
            let want: Vec<u32> = (0..epoch.min(8)).collect();
            assert_eq!(got, want, "epoch {epoch}");
        }
    }

    #[test]
    fn delta_table_concurrent_probe_during_insert() {
        // Smoke the publication protocol: a reader probing while the
        // writer inserts must only ever see fully-published prefixes.
        let table = Arc::new(DeltaTable::with_capacity(4096));
        let t2 = Arc::clone(&table);
        let writer = std::thread::spawn(move || {
            for id in 0..4096u32 {
                // SAFETY: this thread is the only writer.
                unsafe { t2.insert(key_of((id % 7) as u64), id) };
            }
        });
        for _ in 0..2000 {
            for v in 0..7u64 {
                if let Some(b) = table.find_bucket(&key_of(v)) {
                    let mut prev = None;
                    table.walk(b, u32::MAX, |id| {
                        assert_eq!(id % 7, v as u32, "id in wrong bucket");
                        if let Some(p) = prev {
                            assert!(id > p, "chain must ascend");
                        }
                        prev = Some(id);
                    });
                }
            }
        }
        writer.join().unwrap();
        // Final state complete.
        for v in 0..7u64 {
            let b = table.find_bucket(&key_of(v)).unwrap();
            let seen = table.walk(b, u32::MAX, |_| {});
            assert_eq!(seen, 4096 / 7 + usize::from(v < 4096 % 7));
        }
    }

    #[test]
    fn delta_segment_epoch_gates_queries() {
        use crate::engine::native::NativeEngine;
        let dim = 4;
        let extent = Arc::new(Extent::new(dim, 64, 0, 0));
        let spec = LayerSpec::outer_l1(dim, 8, 4, 0.0, 10.0, 3);
        let delta = DeltaSegment::new(&spec, &[0, 1, 2, 3], Arc::clone(&extent), 0);
        let mut rng = Xoshiro256::seed_from_u64(4);
        let pts: Vec<f32> = (0..32 * dim).map(|_| rng.gen_f64(0.0, 10.0) as f32).collect();
        let labels = vec![false; 32];
        extent.append(&pts, &labels);
        delta.index_rows(16); // only half published to queries
        assert_eq!(delta.indexed(), 16);
        let engine = NativeEngine::new();
        let mut scratch = QueryScratch::new(1);
        let mut out = BatchOutput::new();
        // Query = point 20 (inserted but NOT indexed): it must not be its
        // own neighbor; every neighbor id must be < 16.
        let q = &pts[20 * dim..21 * dim];
        delta.query_batch(&engine, q, 5, 1000, &mut scratch, &mut out);
        assert_eq!(out.len(), 1);
        for n in out.neighbors(0) {
            assert!(n.id >= 1000 && n.id < 1016, "epoch leak: {n:?}");
        }
        // After catching up, the point finds itself at distance 0.
        delta.index_rows(32);
        delta.query_batch(&engine, q, 5, 1000, &mut scratch, &mut out);
        assert!(out.neighbors(0).iter().any(|n| n.id == 1020 && n.dist == 0.0));
    }
}
