//! The live (streaming) SLSH index: online inserts without rebuilds.
//!
//! The paper's ICU scenario is inherently streaming — new ABP windows
//! arrive from monitors continuously — yet the batch-built
//! [`SlshIndex`] can only be frozen once. A [`LiveIndex`] closes that gap
//! with an LSM-like segment lifecycle:
//!
//! ```text
//!   inserts ──▶ delta (hash-on-insert, outer tables only)
//!                 │ seal: size OR age (SealPolicy, injectable Clock)
//!                 ▼
//!              sealed segment (full SlshIndex, inner indices built now)
//!                 ▼
//!              sealed stack  ── queries merge every segment's top-K
//! ```
//!
//! Three cooperating pieces:
//!
//! * [`LiveStore`] — the node-level growable point store: a chain of
//!   fixed-capacity [`Extent`]s (points never move, so scan kernels keep
//!   their flat slices) plus the seal decisions. ONE store serves every
//!   core of a node; the store is the single seal authority, so all cores
//!   agree on segment boundaries deterministically.
//! * [`LiveIndex`] — one owner's index over a subset of the outer tables
//!   (a core's `{t : t ≡ i (mod p)}` share, or all tables standalone):
//!   sealed [`SealedSegment`]s + one [`DeltaSegment`].
//!   [`LiveIndex::sync`] catches the tables up with the store — indexing
//!   fresh rows into the delta, and sealing (building a full
//!   [`SlshIndex`], inner indices included) when the store closed an
//!   extent.
//! * [`LiveScratch`] — the reusable per-owner query arena (per-segment
//!   scratch + the cross-segment top-K accumulators).
//!
//! **Epoch-guarded snapshot reads.** Queries never lock against inserts.
//! A query pins an `Arc` snapshot of the segment stack (one brief mutex
//! for the clone), then reads the delta at its `Acquire`-published epoch:
//! the answer is always a valid *prefix* of the insertion order — every
//! neighbor's floats were fully written before the epoch was published,
//! and no point is visible in some tables but not others. Concurrent
//! inserts simply land past the epoch and become visible to the next
//! query.
//!
//! **Cross-segment resolution.** Each segment resolves independently
//! (comparison counting and [`ScanCancel`] budget enforcement intact);
//! per-segment top-Ks are merged through the same reduction the
//! cluster's Reducer uses ([`crate::knn::reduce::fold_partial`]), so
//! results are order-invariant and deduplication semantics match the
//! distributed path exactly.
//!
//! **Seal equivalence.** Sealing rebuilds the segment with
//! [`SlshIndex::build`] over the extent's final points, so an index grown
//! from empty and then sealed answers bit-identically to
//! [`SlshIndex::build_full`] over the same points
//! (`rust/tests/streaming_ingest.rs` pins this across seeds and both
//! LSH/SLSH configs). Before sealing, the delta serves LSH-only
//! semantics on the outer tables — identical candidates in LSH-only
//! configs; stratification (inner indices) kicks in at seal time, when
//! bucket populations are final.
//!
//! [`SlshIndex`]: crate::slsh::index::SlshIndex
//! [`SlshIndex::build`]: crate::slsh::index::SlshIndex::build
//! [`SlshIndex::build_full`]: crate::slsh::index::SlshIndex::build_full

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::engine::{DistanceEngine, ScanCancel};
use crate::knn::heap::TopK;
use crate::knn::reduce::fold_partial;
use crate::lsh::probe::ProbeSpec;
use crate::slsh::index::{BatchOutput, QueryScratch, QueryStats};
use crate::slsh::params::SlshParams;
use crate::slsh::segment::{DeltaSegment, Extent, SealReason, SealedSegment};
use crate::util::clock::Clock;

/// Global-id stride between live nodes: node `i` of a live cluster mints
/// ids from `i * LIVE_ID_STRIDE`, so ids stay disjoint (and stable across
/// local/remote deployments) without a coordinator round trip per insert.
pub const LIVE_ID_STRIDE: u64 = 1 << 40;

/// Lock a mutex, recovering from poisoning. A panicking inserter must not
/// turn every subsequent query into a panic (the graceful-degradation
/// contract): the guarded state here is always an `Arc` swap or a
/// publish-last [`Extent`] append, neither of which can be observed
/// half-written, so taking the inner guard after a poison is sound — the
/// worst case is a snapshot missing the panicked call's unpublished work.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// When the delta seals into an immutable segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SealPolicy {
    /// Seal once the open extent holds this many points (also the
    /// extent's fixed capacity — delta structures never reallocate).
    pub max_points: usize,
    /// Seal once the open extent's FIRST point is this old (ns on the
    /// injected clock); `u64::MAX` disables age sealing.
    pub max_age_ns: u64,
}

impl SealPolicy {
    /// Seal on size only.
    pub fn by_size(max_points: usize) -> SealPolicy {
        assert!(max_points > 0, "seal size must be positive");
        SealPolicy { max_points, max_age_ns: u64::MAX }
    }

    /// Seal on size or age, whichever trips first.
    pub fn by_size_or_age(max_points: usize, max_age: Duration) -> SealPolicy {
        assert!(max_points > 0, "seal size must be positive");
        let ns = max_age.as_nanos().min(u64::MAX as u128) as u64;
        SealPolicy { max_points, max_age_ns: ns }
    }
}

/// What one [`LiveStore::append`] did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AppendOutcome {
    /// Points appended (all of them — the store never drops).
    pub accepted: u64,
    /// Extents closed during this call (size or age trips).
    pub sealed_now: u64,
}

/// Immutable snapshot of the store's extent chain.
struct StoreSnapshot {
    extents: Vec<Arc<Extent>>,
}

/// Node-level growable point store: the seal authority every core's
/// [`LiveIndex`] follows. Appends are serialized by an internal writer
/// lock; readers (worker `sync`s and queries) go through `Arc` snapshots
/// and each extent's published row count, never a lock on data.
pub struct LiveStore {
    dim: usize,
    policy: SealPolicy,
    clock: Arc<dyn Clock>,
    /// Serializes append/close decisions.
    write: Mutex<()>,
    /// Published extent chain (all but the last are closed).
    snap: Mutex<Arc<StoreSnapshot>>,
    /// Total points ever appended.
    total: AtomicU64,
    /// Extents closed so far (== sealed segments once owners sync).
    closed: AtomicU64,
}

impl LiveStore {
    pub fn new(dim: usize, policy: SealPolicy, clock: Arc<dyn Clock>) -> LiveStore {
        assert!(dim > 0, "store needs dim > 0");
        // SealPolicy's fields are pub (the TCP server builds it from wire
        // values), so the constructor invariant is re-checked here — at
        // the source — rather than panicking inside the first extent
        // allocation.
        assert!(policy.max_points > 0, "seal size must be positive");
        LiveStore {
            dim,
            policy,
            clock,
            write: Mutex::new(()),
            snap: Mutex::new(Arc::new(StoreSnapshot { extents: Vec::new() })),
            total: AtomicU64::new(0),
            closed: AtomicU64::new(0),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn policy(&self) -> SealPolicy {
        self.policy
    }

    /// Total points ever appended.
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Acquire)
    }

    /// Extents closed so far.
    pub fn closed_extents(&self) -> u64 {
        self.closed.load(Ordering::Acquire)
    }

    fn snapshot(&self) -> Arc<StoreSnapshot> {
        Arc::clone(&lock_unpoisoned(&self.snap))
    }

    /// Append `labels.len()` points, splitting across extents and closing
    /// any that trip the size policy; an age-due open extent is closed
    /// FIRST so the new points start a fresh one.
    pub fn append(&self, points: &[f32], labels: &[bool]) -> AppendOutcome {
        let n = labels.len();
        assert_eq!(points.len(), n * self.dim, "insert block not n × dim");
        let _g = lock_unpoisoned(&self.write);
        let now = self.clock.now_ns();
        let mut sealed_now = self.close_if_age_due(now);
        let mut off = 0usize;
        while off < n {
            let ext = self.open_extent(now);
            let room = self.policy.max_points - ext.writer_rows();
            let take = room.min(n - off);
            ext.append(
                &points[off * self.dim..(off + take) * self.dim],
                &labels[off..off + take],
            );
            self.total.fetch_add(take as u64, Ordering::Release);
            off += take;
            if ext.writer_rows() == self.policy.max_points {
                self.close_current(SealReason::Size);
                sealed_now += 1;
            }
        }
        AppendOutcome { accepted: n as u64, sealed_now }
    }

    /// Close the open extent if its age bound has passed — the explicit
    /// poll for quiet streams (no timer thread; callers decide when time
    /// is checked, which is what keeps age sealing deterministic under
    /// `MockClock`). Returns the number of extents closed (0 or 1).
    pub fn poll_age(&self) -> u64 {
        let _g = lock_unpoisoned(&self.write);
        self.close_if_age_due(self.clock.now_ns())
    }

    /// Unconditionally close the open extent (if it holds any points).
    /// Returns the number of extents closed (0 or 1).
    pub fn force_seal(&self) -> u64 {
        let _g = lock_unpoisoned(&self.write);
        let snap = self.snapshot();
        match snap.extents.last() {
            Some(ext) if !ext.is_closed() && ext.writer_rows() > 0 => {
                self.close_current(SealReason::Forced);
                1
            }
            _ => 0,
        }
    }

    /// Close the open extent when age-due (write lock held).
    fn close_if_age_due(&self, now: u64) -> u64 {
        if self.policy.max_age_ns == u64::MAX {
            return 0;
        }
        let snap = self.snapshot();
        match snap.extents.last() {
            Some(ext)
                if !ext.is_closed()
                    && ext.writer_rows() > 0
                    && now >= ext.created_ns().saturating_add(self.policy.max_age_ns) =>
            {
                self.close_current(SealReason::Age);
                1
            }
            _ => 0,
        }
    }

    /// The open extent, creating (and publishing) a fresh one if the
    /// chain is empty or its tail is closed (write lock held).
    fn open_extent(&self, now: u64) -> Arc<Extent> {
        let mut snap = lock_unpoisoned(&self.snap);
        if let Some(last) = snap.extents.last() {
            if !last.is_closed() {
                return Arc::clone(last);
            }
        }
        let start = self.total.load(Ordering::Relaxed);
        let ext = Arc::new(Extent::new(self.dim, self.policy.max_points, start, now));
        let mut extents = snap.extents.clone();
        extents.push(Arc::clone(&ext));
        *snap = Arc::new(StoreSnapshot { extents });
        ext
    }

    /// Mark the chain's tail closed (write lock held; tail must be open).
    fn close_current(&self, reason: SealReason) {
        let snap = self.snapshot();
        let last = snap.extents.last().expect("closing with no extent");
        debug_assert!(!last.is_closed());
        last.close(reason);
        self.closed.fetch_add(1, Ordering::Release);
    }
}

/// Reusable query arena for a [`LiveIndex`] owner: the per-segment
/// scratch/output plus the cross-segment top-K accumulators and merged
/// stats. Steady state allocates nothing per query.
pub struct LiveScratch {
    /// Per-segment resolution scratch (visited stamps, candidate buffer,
    /// batch-hash keys, pooled per-query top-Ks).
    seg: QueryScratch,
    /// Per-segment flat output, folded into `acc` after each segment.
    seg_out: BatchOutput,
    /// Cross-segment top-K accumulator, one per query in the batch.
    acc: Vec<TopK>,
    /// Merged per-query stats (comparisons summed across segments).
    stats: Vec<QueryStats>,
}

impl LiveScratch {
    pub fn new() -> LiveScratch {
        LiveScratch {
            seg: QueryScratch::new(1),
            seg_out: BatchOutput::new(),
            acc: Vec::new(),
            stats: Vec::new(),
        }
    }

    fn ensure(&mut self, nq: usize, k: usize) {
        if self.acc.len() < nq {
            let grow = nq - self.acc.len();
            self.acc.extend((0..grow).map(|_| TopK::new(k)));
        }
        if self.stats.len() < nq {
            self.stats.resize(nq, QueryStats::default());
        }
        for qi in 0..nq {
            self.acc[qi].reset(k);
            self.stats[qi] = QueryStats::default();
        }
    }
}

/// What one standalone [`LiveIndex::insert_batch`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertSummary {
    /// Points accepted by this call.
    pub accepted: u64,
    /// Total points in the store afterwards.
    pub total: u64,
    /// Segments sealed by this call.
    pub sealed_now: u64,
    /// Total sealed segments afterwards.
    pub sealed_total: u64,
}

/// Published index snapshot: what one query resolves against.
struct LiveSnap {
    sealed: Vec<Arc<SealedSegment>>,
    delta: Option<Arc<DeltaSegment>>,
}

/// A live, segmented SLSH index over a subset of the outer tables —
/// sealed immutable segments plus one append-only delta. See the
/// [module docs](self) for the lifecycle and consistency contracts.
pub struct LiveIndex {
    params: SlshParams,
    tables: Vec<usize>,
    store: Arc<LiveStore>,
    /// Standalone indexes own their store and may insert/seal through it;
    /// worker-mode indexes follow a node-owned store via [`sync`].
    ///
    /// [`sync`]: LiveIndex::sync
    owns_store: bool,
    id_base: u64,
    /// Serializes index mutation (insert / sync / seal). Queries never
    /// take it.
    write: Mutex<()>,
    /// Published segment stack; queries clone the `Arc` and go.
    snap: Mutex<Arc<LiveSnap>>,
}

impl LiveIndex {
    /// A standalone live index owning all `L` outer tables and its own
    /// store — the single-process streaming front door (see
    /// `examples/quickstart.rs`).
    pub fn new(params: &SlshParams, policy: SealPolicy, clock: Arc<dyn Clock>) -> LiveIndex {
        let tables: Vec<usize> = (0..params.outer.l).collect();
        let store = Arc::new(LiveStore::new(params.outer.dim, policy, clock));
        LiveIndex::with_store_inner(params, &tables, store, 0, true)
    }

    /// A live index over `table_indices`, following a shared node store —
    /// the per-core worker shape. Call [`sync`](LiveIndex::sync) to catch
    /// up with the store's appends and seals.
    pub fn with_store(
        params: &SlshParams,
        table_indices: &[usize],
        store: Arc<LiveStore>,
        id_base: u64,
    ) -> LiveIndex {
        LiveIndex::with_store_inner(params, table_indices, store, id_base, false)
    }

    fn with_store_inner(
        params: &SlshParams,
        table_indices: &[usize],
        store: Arc<LiveStore>,
        id_base: u64,
        owns_store: bool,
    ) -> LiveIndex {
        assert_eq!(store.dim(), params.outer.dim, "store/params dim mismatch");
        LiveIndex {
            params: params.clone(),
            tables: table_indices.to_vec(),
            store,
            owns_store,
            id_base,
            write: Mutex::new(()),
            snap: Mutex::new(Arc::new(LiveSnap { sealed: Vec::new(), delta: None })),
        }
    }

    pub fn params(&self) -> &SlshParams {
        &self.params
    }

    pub fn store(&self) -> &Arc<LiveStore> {
        &self.store
    }

    pub fn id_base(&self) -> u64 {
        self.id_base
    }

    fn snapshot(&self) -> Arc<LiveSnap> {
        Arc::clone(&lock_unpoisoned(&self.snap))
    }

    /// Points this index has fully indexed (sealed rows + delta epoch) —
    /// the upper bound on what a query started NOW can see.
    pub fn len(&self) -> usize {
        let snap = self.snapshot();
        let sealed: usize = snap.sealed.iter().map(|s| s.rows()).sum();
        sealed + snap.delta.as_ref().map(|d| d.indexed()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sealed segments in the published stack.
    pub fn sealed_segments(&self) -> usize {
        self.snapshot().sealed.len()
    }

    /// Points in the (published) delta.
    pub fn delta_len(&self) -> usize {
        self.snapshot().delta.as_ref().map(|d| d.indexed()).unwrap_or(0)
    }

    /// Close reasons of the sealed stack, in seal order (tests pin
    /// size/age triggering through this).
    pub fn seal_reasons(&self) -> Vec<SealReason> {
        self.snapshot().sealed.iter().filter_map(|s| s.close_reason()).collect()
    }

    /// Insert a batch of labeled points (standalone indexes only):
    /// append to the owned store, hash into the delta tables, and seal if
    /// the policy trips. Returns what happened.
    pub fn insert_batch(&self, points: &[f32], labels: &[bool]) -> InsertSummary {
        assert!(
            self.owns_store,
            "insert through the store's owner (the node), not a follower index"
        );
        let out = self.store.append(points, labels);
        self.sync();
        InsertSummary {
            accepted: out.accepted,
            total: self.store.total(),
            sealed_now: out.sealed_now,
            sealed_total: self.store.closed_extents(),
        }
    }

    /// Seal the current delta now (standalone indexes only); no-op when
    /// the delta is empty.
    pub fn seal_now(&self) -> u64 {
        assert!(self.owns_store, "seal through the store's owner (the node)");
        let sealed = self.store.force_seal();
        self.sync();
        sealed
    }

    /// Check the age policy and seal if due (standalone indexes only).
    /// Deterministic: time is only read here and in `insert_batch`, on
    /// the injected clock.
    pub fn maybe_seal(&self) -> u64 {
        assert!(self.owns_store, "seal through the store's owner (the node)");
        let sealed = self.store.poll_age();
        self.sync();
        sealed
    }

    /// Catch this index up with the store: hash newly appended rows into
    /// the delta tables, and convert the delta into a [`SealedSegment`]
    /// (building inner indices) for every extent the store has closed.
    /// Safe to call from the owner thread at any time; queries running
    /// concurrently keep their pinned snapshots.
    pub fn sync(&self) {
        let _g = lock_unpoisoned(&self.write);
        let store_snap = self.store.snapshot();
        let cur = self.snapshot();
        let mut sealed = cur.sealed.clone();
        let mut delta = cur.delta.clone();
        let mut changed = false;
        loop {
            let sidx = sealed.len();
            let Some(ext) = store_snap.extents.get(sidx) else { break };
            // Read `closed` BEFORE the row count: if the close is
            // visible, the count read after it is the extent's final one.
            let closed = ext.is_closed();
            let rows = ext.published_rows();
            if closed {
                // Seal straight from the extent: `SlshIndex::build`
                // re-hashes every row anyway, so hashing them into a
                // delta first (or finishing a half-indexed one) would be
                // pure throwaway work. Any existing delta for this extent
                // is simply dropped from the next snapshot; pinned
                // readers keep theirs.
                let seg =
                    SealedSegment::build(&self.params, &self.tables, Arc::clone(ext), rows);
                sealed.push(Arc::new(seg));
                delta = None;
                changed = true;
                continue; // the next extent may already exist
            }
            let d = match &delta {
                Some(d) if d.extent_idx() == sidx => Arc::clone(d),
                _ => {
                    let d = Arc::new(DeltaSegment::new(
                        &self.params.outer,
                        &self.tables,
                        Arc::clone(ext),
                        sidx,
                    ));
                    delta = Some(Arc::clone(&d));
                    changed = true;
                    d
                }
            };
            d.index_rows(rows);
            break;
        }
        if changed {
            *lock_unpoisoned(&self.snap) = Arc::new(LiveSnap { sealed, delta });
        }
    }

    /// Resolve a block of queries (`qs` row-major `nq × dim`) against the
    /// pinned segment snapshot: every sealed segment resolves on the
    /// regular [`SlshIndex`] path, the delta on its epoch-guarded
    /// hash-on-insert path, and per-segment top-Ks merge through
    /// [`fold_partial`] — the same reduction the cluster's Reducer runs.
    /// `out` holds one entry per query; stats sum comparisons/probes and
    /// count tables across ALL segments.
    pub fn query_batch(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        scratch: &mut LiveScratch,
        out: &mut BatchOutput,
    ) {
        self.query_batch_inner(engine, qs, scratch, out, ProbeSpec::BASELINE, None);
    }

    /// Budget-enforced twin of [`query_batch`](LiveIndex::query_batch):
    /// segments resolve in stack order (sealed oldest-first, delta last)
    /// and the walk stops — remaining segments unvisited, affected
    /// queries flagged `partial` — the moment `cancel`'s deadline blows.
    /// With a deadline that never trips, bit-identical to `query_batch`.
    pub fn query_batch_cancel(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        scratch: &mut LiveScratch,
        out: &mut BatchOutput,
        cancel: &ScanCancel,
    ) {
        self.query_batch_inner(engine, qs, scratch, out, ProbeSpec::BASELINE, Some(cancel));
    }

    /// Knob-carrying twin: every sealed segment resolves through
    /// [`SlshIndex::query_batch_spec`] and the delta through its spec
    /// path, so `probes`/`max_comparisons` apply uniformly across the
    /// whole segment stack. The baseline spec takes the exact legacy
    /// per-segment bodies. Note `max_comparisons` bounds candidates *per
    /// segment* on the live path (each segment is its own index); the
    /// clock-free determinism and prefix contracts hold per segment.
    pub fn query_batch_spec(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        scratch: &mut LiveScratch,
        out: &mut BatchOutput,
        spec: ProbeSpec,
        cancel: Option<&ScanCancel>,
    ) {
        self.query_batch_inner(engine, qs, scratch, out, spec, cancel);
    }

    fn query_batch_inner(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        scratch: &mut LiveScratch,
        out: &mut BatchOutput,
        spec: ProbeSpec,
        cancel: Option<&ScanCancel>,
    ) {
        let dim = self.params.outer.dim;
        assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
        let nq = qs.len() / dim;
        let k = self.params.k;
        let snap = self.snapshot();
        scratch.ensure(nq, k);
        let mut cut = false;
        for seg in &snap.sealed {
            if Self::blown(cancel) {
                cut = true;
                break;
            }
            seg.index.query_batch_spec(
                engine,
                qs,
                seg.data(),
                seg.labels(),
                self.id_base + seg.start(),
                spec,
                &mut scratch.seg,
                &mut scratch.seg_out,
                cancel,
            );
            fold_segment(&mut scratch.acc, &mut scratch.stats, &scratch.seg_out);
        }
        if let Some(delta) = &snap.delta {
            if !cut && Self::blown(cancel) {
                cut = true;
            }
            if !cut {
                delta.query_batch_spec(
                    engine,
                    qs,
                    k,
                    self.id_base,
                    spec,
                    &mut scratch.seg,
                    &mut scratch.seg_out,
                    cancel,
                );
                fold_segment(&mut scratch.acc, &mut scratch.stats, &scratch.seg_out);
            }
        }
        if cut {
            // Segments skipped wholesale: every query's answer misses
            // them — flag the whole batch partial.
            for qi in 0..nq {
                scratch.stats[qi].partial = true;
            }
        }
        out.clear();
        for qi in 0..nq {
            out.push_query(&mut scratch.acc[qi], scratch.stats[qi]);
        }
    }

    fn blown(cancel: Option<&ScanCancel>) -> bool {
        cancel.map(|c| c.blown()).unwrap_or(false)
    }
}

/// Fold one segment's flat batch output into the cross-segment
/// accumulators: neighbors through the Reducer's merge
/// ([`fold_partial`]), stats by summation (`partial` is sticky).
fn fold_segment(acc: &mut [TopK], stats: &mut [QueryStats], seg_out: &BatchOutput) {
    for qi in 0..seg_out.len() {
        fold_partial(&mut acc[qi], seg_out.neighbors(qi));
        let s = seg_out.stats(qi);
        stats[qi].comparisons += s.comparisons;
        stats[qi].inner_probes += s.inner_probes;
        stats[qi].direct_buckets += s.direct_buckets;
        stats[qi].tables += s.tables;
        stats[qi].partial |= s.partial;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeEngine;
    use crate::lsh::family::LayerSpec;
    use crate::slsh::index::SlshIndex;
    use crate::slsh::params::InnerParams;
    use crate::util::clock::MockClock;
    use crate::util::rng::Xoshiro256;

    fn clustered(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<bool>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = Vec::with_capacity(n * dim);
        let mut labels = Vec::with_capacity(n);
        let centers: Vec<Vec<f32>> = (0..12)
            .map(|_| (0..dim).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect())
            .collect();
        for i in 0..n {
            let c = &centers[rng.gen_index(centers.len())];
            for &v in c {
                data.push(v + rng.gen_normal(0.0, 0.5) as f32);
            }
            labels.push(i % 9 == 0);
        }
        (data, labels)
    }

    fn lsh_params(dim: usize, m: usize, l: usize, seed: u64) -> SlshParams {
        SlshParams::lsh_only(LayerSpec::outer_l1(dim, m, l, 20.0, 180.0, seed), 10)
    }

    fn slsh_params(dim: usize, seed: u64) -> SlshParams {
        SlshParams {
            outer: LayerSpec::outer_l1(dim, 12, 8, 20.0, 180.0, seed),
            inner: Some(InnerParams { m: 24, l: 8, alpha: 0.05, seed: seed ^ 0xBEEF }),
            k: 10,
        }
    }

    fn mock_clock() -> Arc<MockClock> {
        Arc::new(MockClock::new(0))
    }

    #[test]
    fn empty_index_answers_empty() {
        let params = lsh_params(30, 16, 8, 3);
        let live = LiveIndex::new(&params, SealPolicy::by_size(64), mock_clock());
        assert!(live.is_empty());
        let engine = NativeEngine::new();
        let mut scratch = LiveScratch::new();
        let mut out = BatchOutput::new();
        let qs: Vec<f32> = (0..2 * 30).map(|i| 40.0 + (i % 30) as f32).collect();
        live.query_batch(&engine, &qs, &mut scratch, &mut out);
        assert_eq!(out.len(), 2);
        for qi in 0..2 {
            assert!(out.neighbors(qi).is_empty());
            assert_eq!(out.stats(qi).comparisons, 0);
        }
    }

    #[test]
    fn seal_by_size_segments_deterministically() {
        let dim = 30;
        let (data, labels) = clustered(200, dim, 5);
        let params = lsh_params(dim, 16, 8, 7);
        let live = LiveIndex::new(&params, SealPolicy::by_size(64), mock_clock());
        let mut sealed_seen = 0;
        for chunk in 0..(200 / 10) {
            let r = chunk * 10;
            let s = live.insert_batch(&data[r * dim..(r + 10) * dim], &labels[r..r + 10]);
            sealed_seen += s.sealed_now;
        }
        assert_eq!(live.len(), 200);
        assert_eq!(live.sealed_segments(), 3, "200 / 64 = 3 full extents");
        assert_eq!(sealed_seen, 3);
        assert_eq!(live.delta_len(), 200 - 3 * 64);
        assert_eq!(live.seal_reasons(), vec![SealReason::Size; 3]);
    }

    #[test]
    fn seal_by_age_uses_injected_clock() {
        let dim = 30;
        let (data, labels) = clustered(20, dim, 6);
        let params = lsh_params(dim, 16, 8, 9);
        let clock = mock_clock();
        let policy = SealPolicy::by_size_or_age(1000, Duration::from_millis(5));
        let live = LiveIndex::new(&params, policy, Arc::clone(&clock) as Arc<dyn Clock>);
        live.insert_batch(&data[..10 * dim], &labels[..10]);
        assert_eq!(live.sealed_segments(), 0);
        // Not due yet: 1ns short of the bound.
        clock.advance(Duration::from_millis(5) - Duration::from_nanos(1));
        assert_eq!(live.maybe_seal(), 0);
        clock.advance(Duration::from_nanos(1));
        assert_eq!(live.maybe_seal(), 1);
        assert_eq!(live.seal_reasons(), vec![SealReason::Age]);
        assert_eq!(live.delta_len(), 0);
        // The NEXT insert lands in a fresh extent; its age clock starts
        // now, and an overdue extent closes on the insert path too.
        live.insert_batch(&data[10 * dim..], &labels[10..]);
        assert_eq!(live.sealed_segments(), 1);
        clock.advance(Duration::from_millis(6));
        let s = live.insert_batch(&data[..dim], &labels[..1]);
        assert_eq!(s.sealed_now, 1, "insert closes the overdue extent first");
        assert_eq!(live.sealed_segments(), 2);
        assert_eq!(live.delta_len(), 1);
    }

    #[test]
    fn spec_baseline_matches_query_batch_and_probes_widen_live_candidates() {
        let dim = 30;
        let (data, labels) = clustered(300, dim, 21);
        let params = lsh_params(dim, 12, 8, 23);
        // 64-cap ⇒ mixed stack: sealed segments AND a live delta.
        let live = LiveIndex::new(&params, SealPolicy::by_size(64), mock_clock());
        for chunk in data.chunks(50 * dim).zip(labels.chunks(50)) {
            live.insert_batch(chunk.0, chunk.1);
        }
        assert!(live.sealed_segments() > 0 && live.delta_len() > 0);
        let engine = NativeEngine::new();
        let mut scratch = LiveScratch::new();
        let (mut plain, mut spec_out) = (BatchOutput::new(), BatchOutput::new());
        let qs = data[..4 * dim].to_vec();
        live.query_batch(&engine, &qs, &mut scratch, &mut plain);
        live.query_batch_spec(&engine, &qs, &mut scratch, &mut spec_out, ProbeSpec::BASELINE, None);
        for qi in 0..4 {
            assert_eq!(spec_out.stats(qi), plain.stats(qi));
            assert_eq!(spec_out.neighbors(qi), plain.neighbors(qi));
        }
        // More probes never scan fewer candidates, on sealed AND delta
        // segments alike; repeated runs are bit-identical.
        let mut prev = vec![0u64; 4];
        for probes in [1u32, 2, 4, 8] {
            let spec = ProbeSpec::new(probes, 0);
            live.query_batch_spec(&engine, &qs, &mut scratch, &mut spec_out, spec, None);
            let mut again = BatchOutput::new();
            live.query_batch_spec(&engine, &qs, &mut scratch, &mut again, spec, None);
            for qi in 0..4 {
                let c = spec_out.stats(qi).comparisons;
                assert!(c >= prev[qi], "P={probes} qi={qi}: {c} < {:?}", prev[qi]);
                prev[qi] = c;
                assert_eq!(again.stats(qi), spec_out.stats(qi));
                assert_eq!(again.neighbors(qi), spec_out.neighbors(qi));
            }
        }
    }

    #[test]
    fn grown_then_sealed_matches_build_full() {
        // The seal-equivalence contract, at unit scope (the integration
        // suite sweeps seeds and configs on real corpus data).
        let dim = 30;
        let (data, labels) = clustered(600, dim, 11);
        for params in [lsh_params(dim, 16, 8, 13), slsh_params(dim, 13)] {
            let live = LiveIndex::new(&params, SealPolicy::by_size(600), mock_clock());
            for chunk in data.chunks(97 * dim).zip(labels.chunks(97)) {
                live.insert_batch(chunk.0, chunk.1);
            }
            assert_eq!(live.sealed_segments(), 1, "cap reached exactly at n");
            assert_eq!(live.delta_len(), 0);
            let reference = SlshIndex::build_full(
                &params,
                &crate::lsh::layer::SliceView { data: &data, dim },
            );
            let engine = NativeEngine::new();
            let mut live_scr = LiveScratch::new();
            let mut live_out = BatchOutput::new();
            let mut ref_scr = QueryScratch::new(600);
            let mut ref_out = BatchOutput::new();
            let mut rng = Xoshiro256::seed_from_u64(15);
            let qs: Vec<f32> = (0..5 * dim).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
            live.query_batch(&engine, &qs, &mut live_scr, &mut live_out);
            reference.query_batch(&engine, &qs, &data, &labels, 0, &mut ref_scr, &mut ref_out);
            for qi in 0..5 {
                assert_eq!(live_out.neighbors(qi), ref_out.neighbors(qi), "qi={qi}");
                assert_eq!(live_out.stats(qi), ref_out.stats(qi), "qi={qi}");
            }
        }
    }

    #[test]
    fn delta_matches_build_full_in_lsh_only_mode() {
        // Before sealing, the delta's outer tables hold exactly the same
        // buckets (same hash instances, same insertion order) as a batch
        // build — LSH-only answers are bit-identical.
        let dim = 30;
        let (data, labels) = clustered(400, dim, 17);
        let params = lsh_params(dim, 20, 12, 19);
        let live = LiveIndex::new(&params, SealPolicy::by_size(4096), mock_clock());
        live.insert_batch(&data, &labels);
        assert_eq!(live.sealed_segments(), 0);
        assert_eq!(live.delta_len(), 400);
        let reference =
            SlshIndex::build_full(&params, &crate::lsh::layer::SliceView { data: &data, dim });
        let engine = NativeEngine::new();
        let mut live_scr = LiveScratch::new();
        let mut live_out = BatchOutput::new();
        let mut ref_scr = QueryScratch::new(400);
        let mut ref_out = BatchOutput::new();
        let mut rng = Xoshiro256::seed_from_u64(21);
        let qs: Vec<f32> = (0..7 * dim).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
        live.query_batch(&engine, &qs, &mut live_scr, &mut live_out);
        reference.query_batch(&engine, &qs, &data, &labels, 0, &mut ref_scr, &mut ref_out);
        for qi in 0..7 {
            assert_eq!(live_out.neighbors(qi), ref_out.neighbors(qi), "qi={qi}");
            assert_eq!(live_out.stats(qi), ref_out.stats(qi), "qi={qi}");
        }
    }

    #[test]
    fn segmented_answers_cover_all_segments() {
        // With several sealed segments + a delta, a point inserted in any
        // segment must find itself at distance 0.
        let dim = 30;
        let (data, labels) = clustered(300, dim, 23);
        let params = lsh_params(dim, 16, 8, 25);
        let live = LiveIndex::new(&params, SealPolicy::by_size(90), mock_clock());
        live.insert_batch(&data, &labels);
        assert_eq!(live.sealed_segments(), 3);
        assert_eq!(live.delta_len(), 30);
        let engine = NativeEngine::new();
        let mut scratch = LiveScratch::new();
        let mut out = BatchOutput::new();
        for probe in [0usize, 89, 90, 179, 270, 299] {
            let q = &data[probe * dim..(probe + 1) * dim];
            live.query_batch(&engine, q, &mut scratch, &mut out);
            let nbs = out.neighbors(0);
            assert!(
                nbs.iter().any(|n| n.id == probe as u64 && n.dist == 0.0),
                "point {probe} must find itself: {nbs:?}"
            );
            // 8 owned tables per segment × 4 segments.
            assert_eq!(out.stats(0).tables, 32);
        }
    }

    #[test]
    fn worker_follower_sync_matches_owner() {
        // Two follower indexes over disjoint table subsets of a shared
        // store must jointly cover exactly what a full owner sees.
        let dim = 30;
        let (data, labels) = clustered(150, dim, 27);
        let params = lsh_params(dim, 16, 8, 29);
        let clock = mock_clock();
        let store = Arc::new(LiveStore::new(dim, SealPolicy::by_size(60), clock));
        let even: Vec<usize> = (0..8).filter(|t| t % 2 == 0).collect();
        let odd: Vec<usize> = (0..8).filter(|t| t % 2 == 1).collect();
        let a = LiveIndex::with_store(&params, &even, Arc::clone(&store), 0);
        let b = LiveIndex::with_store(&params, &odd, Arc::clone(&store), 0);
        store.append(&data, &labels);
        a.sync();
        b.sync();
        assert_eq!(a.len(), 150);
        assert_eq!(b.len(), 150);
        assert_eq!(a.sealed_segments(), 2);
        assert_eq!(b.sealed_segments(), 2);
        let engine = NativeEngine::new();
        let (mut sa, mut sb) = (LiveScratch::new(), LiveScratch::new());
        let (mut oa, mut ob) = (BatchOutput::new(), BatchOutput::new());
        let full = LiveIndex::new(&params, SealPolicy::by_size(60), mock_clock());
        full.insert_batch(&data, &labels);
        let (mut sf, mut of) = (LiveScratch::new(), BatchOutput::new());
        let mut rng = Xoshiro256::seed_from_u64(31);
        for _ in 0..10 {
            let q: Vec<f32> = (0..dim).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
            a.query_batch(&engine, &q, &mut sa, &mut oa);
            b.query_batch(&engine, &q, &mut sb, &mut ob);
            full.query_batch(&engine, &q, &mut sf, &mut of);
            let mut merged = TopK::new(params.k);
            fold_partial(&mut merged, oa.neighbors(0));
            fold_partial(&mut merged, ob.neighbors(0));
            assert_eq!(merged.into_sorted(), of.neighbors(0));
            // Owners dedup only within their own table subsets, so their
            // summed comparison counts can only exceed the full owner's.
            assert!(oa.stats(0).comparisons + ob.stats(0).comparisons >= of.stats(0).comparisons);
        }
    }

    #[test]
    fn cancel_unbounded_is_bit_identical_and_blown_is_empty_partial() {
        let dim = 30;
        let (data, labels) = clustered(240, dim, 33);
        let params = lsh_params(dim, 16, 8, 35);
        let live = LiveIndex::new(&params, SealPolicy::by_size(80), mock_clock());
        live.insert_batch(&data, &labels);
        let engine = NativeEngine::new();
        let mut scratch = LiveScratch::new();
        let (mut plain, mut enforced) = (BatchOutput::new(), BatchOutput::new());
        let mut rng = Xoshiro256::seed_from_u64(37);
        let qs: Vec<f32> = (0..3 * dim).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
        live.query_batch(&engine, &qs, &mut scratch, &mut plain);
        let unbounded = ScanCancel::unbounded(mock_clock());
        live.query_batch_cancel(&engine, &qs, &mut scratch, &mut enforced, &unbounded);
        for qi in 0..3 {
            assert_eq!(enforced.neighbors(qi), plain.neighbors(qi));
            assert_eq!(enforced.stats(qi), plain.stats(qi));
            assert!(!enforced.stats(qi).partial);
        }
        // Deadline already blown: zero work, everything partial.
        let blown = ScanCancel::until(Arc::new(MockClock::new(10)), 10);
        live.query_batch_cancel(&engine, &qs, &mut scratch, &mut enforced, &blown);
        for qi in 0..3 {
            assert!(enforced.stats(qi).partial);
            assert_eq!(enforced.stats(qi).comparisons, 0);
            assert!(enforced.neighbors(qi).is_empty());
        }
    }

    #[test]
    fn poisoned_locks_do_not_take_down_readers() {
        // A panicking inserter poisons every mutex it held; queries and
        // later inserts must recover the guards and keep serving — the
        // PR 6 graceful-degradation contract reaches the lock layer.
        let dim = 30;
        let (data, labels) = clustered(200, dim, 41);
        let params = lsh_params(dim, 16, 8, 43);
        let live = LiveIndex::new(&params, SealPolicy::by_size(80), mock_clock());
        live.insert_batch(&data[..150 * dim], &labels[..150]);
        let engine = NativeEngine::new();
        let mut scratch = LiveScratch::new();
        let mut before = BatchOutput::new();
        let qs = data[..2 * dim].to_vec();
        live.query_batch(&engine, &qs, &mut scratch, &mut before);
        // Simulate the inserter dying mid-flight while holding every lock
        // on the index AND its store; the caught panic leaves all four
        // mutexes poisoned.
        let crashed = std::thread::scope(|s| {
            s.spawn(|| {
                let _iw = live.write.lock().unwrap();
                let _is = live.snap.lock().unwrap();
                let _sw = live.store.write.lock().unwrap();
                let _ss = live.store.snap.lock().unwrap();
                panic!("inserter died mid-flight");
            })
            .join()
        });
        assert!(crashed.is_err(), "the panic must have fired");
        assert!(live.snap.lock().is_err(), "snap mutex is really poisoned");
        // Readers recover: same snapshot, same answers, no panic.
        let mut after = BatchOutput::new();
        live.query_batch(&engine, &qs, &mut scratch, &mut after);
        for qi in 0..2 {
            assert_eq!(after.neighbors(qi), before.neighbors(qi));
            assert_eq!(after.stats(qi), before.stats(qi));
        }
        // Writers recover too: inserts and seals keep working past the
        // poison, and the new points become visible.
        let s = live.insert_batch(&data[150 * dim..], &labels[150..]);
        assert_eq!(s.accepted, 50);
        assert_eq!(live.len(), 200);
        assert_eq!(live.seal_now(), 1);
        let probe = 199;
        let q = &data[probe * dim..(probe + 1) * dim];
        let mut out = BatchOutput::new();
        live.query_batch(&engine, q, &mut scratch, &mut out);
        assert!(
            out.neighbors(0).iter().any(|n| n.id == probe as u64 && n.dist == 0.0),
            "post-poison insert must be queryable"
        );
    }
}
