//! SLSH parameter sets, their JSON round-trip (configs, wire protocol) and
//! the paper's experiment grids.

use crate::lsh::family::LayerSpec;
use crate::util::json::{Json, JsonObj};

/// Inner-layer (stratification) parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct InnerParams {
    /// Bits per inner composed hash (`m_in`).
    pub m: usize,
    /// Inner tables per stratified bucket (`L_in`).
    pub l: usize,
    /// Population threshold: buckets with more than `alpha · n_local`
    /// points get an inner index (`α`, paper uses 0.005).
    pub alpha: f64,
    /// Seed stream for inner family draws.
    pub seed: u64,
}

/// Full SLSH configuration: outer L1 layer + optional inner cosine layer +
/// K for K-NN.
#[derive(Debug, Clone, PartialEq)]
pub struct SlshParams {
    pub outer: LayerSpec,
    pub inner: Option<InnerParams>,
    /// Neighbors retrieved per query (paper: K = 10).
    pub k: usize,
}

impl SlshParams {
    /// LSH-only configuration (Figure 3 sweeps).
    pub fn lsh_only(outer: LayerSpec, k: usize) -> Self {
        Self { outer, inner: None, k }
    }

    /// The paper's *SLSH onset*: the outer configuration on which the
    /// inner layer is applied (m_out = 125, L_out = 120, α = 0.005).
    pub fn paper_onset(dim: usize, lo: f32, hi: f32, seed: u64) -> Self {
        Self {
            outer: LayerSpec::outer_l1(dim, 125, 120, lo, hi, seed),
            inner: Some(InnerParams { m: 65, l: 20, alpha: 0.005, seed: seed ^ 0x1111_2222 }),
            k: 10,
        }
    }

    // ---- JSON round-trip --------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        let mut outer = JsonObj::new();
        outer.insert("dim", Json::Num(self.outer.dim as f64));
        outer.insert("m", Json::Num(self.outer.m as f64));
        outer.insert("l", Json::Num(self.outer.l as f64));
        outer.insert("lo", Json::Num(self.outer.lo as f64));
        outer.insert("hi", Json::Num(self.outer.hi as f64));
        outer.insert("seed", Json::Num(self.outer.seed as f64));
        o.insert("outer", Json::Obj(outer));
        if let Some(inner) = &self.inner {
            let mut i = JsonObj::new();
            i.insert("m", Json::Num(inner.m as f64));
            i.insert("l", Json::Num(inner.l as f64));
            i.insert("alpha", Json::Num(inner.alpha));
            i.insert("seed", Json::Num(inner.seed as f64));
            o.insert("inner", Json::Obj(i));
        }
        o.insert("k", Json::Num(self.k as f64));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        let outer = v.get("outer")?;
        let spec = LayerSpec::outer_l1(
            outer.get("dim")?.as_usize()?,
            outer.get("m")?.as_usize()?,
            outer.get("l")?.as_usize()?,
            outer.get("lo")?.as_f64()? as f32,
            outer.get("hi")?.as_f64()? as f32,
            outer.get("seed")?.as_u64()?,
        );
        let inner = match v.get("inner") {
            Some(i) => Some(InnerParams {
                m: i.get("m")?.as_usize()?,
                l: i.get("l")?.as_usize()?,
                alpha: i.get("alpha")?.as_f64()?,
                seed: i.get("seed")?.as_u64()?,
            }),
            None => None,
        };
        Some(Self { outer: spec, inner, k: v.get("k")?.as_usize()? })
    }
}

/// The paper's Figure 3 outer grid:
/// m_out ∈ {100, 125, 150, 175, 200} × L_out ∈ {72, 96, 120}.
pub fn fig3_outer_grid() -> Vec<(usize, usize)> {
    let ms = [100, 125, 150, 175, 200];
    let ls = [72, 96, 120];
    let mut grid = Vec::new();
    for &m in &ms {
        for &l in &ls {
            grid.push((m, l));
        }
    }
    grid
}

/// The paper's Figure 4 inner grid at the SLSH onset:
/// m_in ∈ {40, 65, 90, 115} × L_in ∈ {20, 60}, α = 0.005.
pub fn fig4_inner_grid() -> Vec<(usize, usize)> {
    let ms = [40, 65, 90, 115];
    let ls = [20, 60];
    let mut grid = Vec::new();
    for &m in &ms {
        for &l in &ls {
            grid.push((m, l));
        }
    }
    grid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_with_inner() {
        let p = SlshParams::paper_onset(30, 20.0, 180.0, 99);
        let j = p.to_json();
        let back = SlshParams::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn json_roundtrip_lsh_only() {
        let p = SlshParams::lsh_only(LayerSpec::outer_l1(30, 150, 96, 25.0, 170.0, 3), 10);
        let back = SlshParams::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        assert!(back.inner.is_none());
    }

    #[test]
    fn grids_match_paper() {
        assert_eq!(fig3_outer_grid().len(), 15);
        assert!(fig3_outer_grid().contains(&(125, 120))); // the SLSH onset
        assert_eq!(fig4_inner_grid().len(), 8);
        assert!(fig4_inner_grid().contains(&(65, 20)));
    }

    #[test]
    fn from_json_rejects_malformed() {
        let v = Json::parse(r#"{"outer": {"dim": 30}, "k": 10}"#).unwrap();
        assert!(SlshParams::from_json(&v).is_none());
    }
}
