//! Stratified Locality Sensitive Hashing (paper §2, Kim et al. [10]).
//!
//! SLSH layers a second, different-metric LSH **inside** the most populous
//! buckets of the outer layer: buckets holding more than `α·n` points get
//! an inner cosine-LSH index over their population, so a query landing in
//! a huge bucket is narrowed by a second notion of similarity instead of
//! linearly scanning the whole bucket. This both cuts candidate counts
//! (the LSH bottleneck) and injects a second metric's semantics.

pub mod index;
pub mod params;

pub use index::{BatchOutput, QueryOutput, QueryScratch, QueryStats, SlshIndex};
pub use params::{InnerParams, SlshParams};
