//! Stratified Locality Sensitive Hashing (paper §2, Kim et al. [10]) —
//! batch-built and live (streaming) indexes.
//!
//! SLSH layers a second, different-metric LSH **inside** the most populous
//! buckets of the outer layer: buckets holding more than `α·n` points get
//! an inner cosine-LSH index over their population, so a query landing in
//! a huge bucket is narrowed by a second notion of similarity instead of
//! linearly scanning the whole bucket. This both cuts candidate counts
//! (the LSH bottleneck) and injects a second metric's semantics.
//!
//! # Index lifecycles
//!
//! Two front doors share one resolution path:
//!
//! * **Batch-built** — [`SlshIndex::build`] / [`build_full`] freeze an
//!   index over a static point set in one shot (tables built in parallel
//!   across cores, inner indices where populations exceed `α·n`). This is
//!   the shape a [`LocalNode`] constructs at cluster build time.
//! * **Live (streaming)** — [`LiveIndex`] accepts online inserts and runs
//!   an LSM-like segment lifecycle:
//!
//!   ```text
//!   delta  ──seal (size OR age)──▶  sealed segment  ──▶  sealed stack
//!   ```
//!
//!   New points hash straight into the **delta**'s growable outer tables
//!   ([`segment`]: hash-on-insert, epoch-published so concurrent queries
//!   never see torn state); when the delta trips its [`SealPolicy`]
//!   — by size, or by age on an injectable [`Clock`] — it is **sealed**:
//!   rebuilt as a regular [`SlshIndex`] (inner stratified indices are
//!   built now, when bucket populations are final) and pushed onto the
//!   immutable sealed stack. Queries resolve every sealed segment plus
//!   the delta and merge per-segment top-Ks through the cluster Reducer's
//!   fold — comparison counting and [`ScanCancel`] budget enforcement
//!   intact across segments. An index grown from empty and then sealed
//!   answers bit-identically to a batch build over the same points
//!   (`rust/tests/streaming_ingest.rs`).
//!
//! Nodes expose the live shape end to end: a growable [`LiveStore`] per
//! node (the seal authority all cores follow), `WorkerMsg::Insert`
//! fan-out, `InsertBatch`/`InsertAck` wire frames, and
//! `Orchestrator::insert_batch` shard routing — see
//! [`crate::node`], [`crate::net::wire`] and [`crate::coordinator`].
//!
//! [`build_full`]: SlshIndex::build_full
//! [`LocalNode`]: crate::node::node::LocalNode
//! [`Clock`]: crate::util::clock::Clock
//! [`ScanCancel`]: crate::engine::ScanCancel

pub mod index;
pub mod live;
pub mod params;
pub mod segment;

pub use index::{BatchOutput, QueryOutput, QueryScratch, QueryStats, SlshIndex};
pub use live::{
    AppendOutcome, InsertSummary, LiveIndex, LiveScratch, LiveStore, SealPolicy, LIVE_ID_STRIDE,
};
pub use params::{InnerParams, SlshParams};
pub use segment::{DeltaSegment, Extent, SealReason, SealedSegment};
