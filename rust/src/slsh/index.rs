//! The SLSH index owned by one (simulated) core: a subset of the outer
//! layer's tables plus inner cosine indices inside populous buckets, and
//! the query-resolution path with comparison counting.
//!
//! Distance work is delegated to the injected [`DistanceEngine`]; the
//! engine's kernel dispatch (scalar vs SIMD, see
//! [`crate::engine::ScanKernel`]) is transparent here — candidate
//! gathering, dedup order and comparison counts are identical under
//! every bit-identical kernel, so the index's bit-identity contracts
//! hold regardless of which ISA ran the scan.

use std::collections::HashMap;

use crate::engine::{DistanceEngine, Metric, ScanCancel};
use crate::knn::heap::{Neighbor, TopK};
use crate::lsh::family::LayerSpec;
use crate::lsh::key::PackedKey;
use crate::lsh::layer::{LshLayer, Points, SliceView};
use crate::lsh::probe::{ProbeGen, ProbeSpec};
use crate::slsh::params::SlshParams;
use crate::util::rng::mix64;
use crate::util::stamp::StampSet;

/// Inner index over one populous outer bucket.
struct InnerIndex {
    /// Local ids of the bucket population (positions are the inner layer's
    /// point ids).
    members: Vec<u32>,
    layer: LshLayer,
}

/// Per-(owned table) map: outer bucket index → inner index.
type InnerMap = HashMap<usize, InnerIndex>;

/// SLSH index over a shard, for a subset of the outer tables.
pub struct SlshIndex {
    pub params: SlshParams,
    outer: LshLayer,
    inners: Vec<InnerMap>,
    /// Number of points in the local shard.
    n_local: usize,
    /// How many inner indices were built (diagnostics).
    pub inner_count: usize,
}

/// Per-query resolution statistics — including the completion metadata
/// budget enforcement reports (how much of the index this answer covers).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Deduplicated candidates scanned — equals distance comparisons
    /// actually performed (under enforcement this can be less than the
    /// candidate set gathered).
    pub comparisons: u64,
    /// Outer buckets that hit an inner index.
    pub inner_probes: u64,
    /// Outer buckets that were taken whole.
    pub direct_buckets: u64,
    /// Owned outer tables this query consulted — equals the number of
    /// owned tables unless budget enforcement cut the resolution short.
    pub tables: u32,
    /// True when budget enforcement stopped this query before it covered
    /// every owned table (the answer is a table-prefix, see
    /// [`SlshIndex::query_batch_cancel`]).
    pub partial: bool,
}

/// K-NN output of one core for one query.
#[derive(Debug, Clone)]
pub struct QueryOutput {
    pub topk: TopK,
    pub stats: QueryStats,
}

/// Reusable per-core scratch for query resolution — the arena the batched
/// path recycles so steady-state serving performs no per-query heap
/// allocations: the visited stamps, candidate buffer, packed hash keys
/// and pooled top-K heaps all keep their capacity across batches.
pub struct QueryScratch {
    pub(crate) visited: StampSet,
    pub(crate) cand: Vec<u32>,
    pub(crate) keys: Vec<PackedKey>,
    pub(crate) topks: Vec<TopK>,
    /// Multi-probe scratch: per-bit flip margins of the current
    /// (query, table), the generated probe keys, and the reusable
    /// sort/heap state of the sequence generator.
    pub(crate) margins: Vec<f32>,
    pub(crate) probe_keys: Vec<PackedKey>,
    pub(crate) probe: ProbeGen,
}

impl QueryScratch {
    /// `n_local` is the shard size the visited set must cover (it grows
    /// on demand if the index is larger).
    pub fn new(n_local: usize) -> Self {
        Self {
            visited: StampSet::new(n_local.max(1)),
            cand: Vec::new(),
            keys: Vec::new(),
            topks: Vec::new(),
            margins: Vec::new(),
            probe_keys: Vec::new(),
            probe: ProbeGen::new(),
        }
    }

    pub(crate) fn ensure(&mut self, n_local: usize, nq: usize, k: usize) {
        self.visited.ensure_capacity(n_local);
        if self.topks.len() < nq {
            let grow = nq - self.topks.len();
            self.topks.extend((0..grow).map(|_| TopK::new(k)));
        }
    }
}

/// Flat, reusable results of one resolved batch: per-query neighbor
/// slices (CSR layout) plus stats. Cleared and refilled in place so the
/// batched path allocates nothing per query once warmed up.
#[derive(Debug, Clone, Default)]
pub struct BatchOutput {
    neighbors: Vec<Neighbor>,
    /// `offsets.len() == len() + 1`, leading 0.
    offsets: Vec<u32>,
    stats: Vec<QueryStats>,
}

impl BatchOutput {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of resolved queries.
    pub fn len(&self) -> usize {
        self.stats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stats.is_empty()
    }

    /// Sorted neighbors of query `qi` (ascending (dist, id) — exactly
    /// what the sequential path's `topk.into_sorted()` yields).
    pub fn neighbors(&self, qi: usize) -> &[Neighbor] {
        let lo = self.offsets[qi] as usize;
        let hi = self.offsets[qi + 1] as usize;
        &self.neighbors[lo..hi]
    }

    pub fn stats(&self, qi: usize) -> QueryStats {
        self.stats[qi]
    }

    /// Flat CSR views, for shipping a whole batch in one message.
    pub fn flat(&self) -> (&[Neighbor], &[u32], &[QueryStats]) {
        (&self.neighbors, &self.offsets, &self.stats)
    }

    pub(crate) fn clear(&mut self) {
        self.neighbors.clear();
        self.offsets.clear();
        self.offsets.push(0);
        self.stats.clear();
    }

    pub(crate) fn push_query(&mut self, topk: &mut TopK, stats: QueryStats) {
        topk.drain_sorted_into(&mut self.neighbors);
        self.offsets.push(self.neighbors.len() as u32);
        self.stats.push(stats);
    }
}

impl SlshIndex {
    /// Build the index for the owned `table_indices` of the outer layer
    /// over the shard `points` (local ids `0..n`).
    ///
    /// Inner indices are built "sequentially where the population is
    /// larger than nα" (paper §3) — n here is the *local* shard size, so
    /// stratification behaves identically at every node count.
    pub fn build<P: Points + ?Sized>(
        params: &SlshParams,
        points: &P,
        table_indices: &[usize],
    ) -> Self {
        let outer = LshLayer::build(&params.outer, points, table_indices);
        let n_local = points.len();
        let mut inners: Vec<InnerMap> = Vec::with_capacity(outer.tables.len());
        let mut inner_count = 0usize;
        if let Some(ip) = &params.inner {
            let threshold = (ip.alpha * n_local as f64).max(1.0) as usize;
            for lt in &outer.tables {
                let mut map = InnerMap::new();
                for (b, ids) in lt.table.buckets() {
                    if ids.len() <= threshold {
                        continue;
                    }
                    // Gather the bucket population into a dense matrix for
                    // the inner build.
                    let dim = points.dim();
                    let mut sub = Vec::with_capacity(ids.len() * dim);
                    for &id in ids {
                        sub.extend_from_slice(points.point(id as usize));
                    }
                    let view = SliceView { data: &sub, dim };
                    // Inner seed: deterministic in (inner seed, global table
                    // index, bucket id) — invariant to core partitioning.
                    let seed = mix64(ip.seed ^ mix64(lt.t as u64) ^ (b as u64));
                    let spec = LayerSpec::inner_cosine(dim, ip.m, ip.l, seed);
                    let layer = LshLayer::build_full(&spec, &view);
                    map.insert(b, InnerIndex { members: ids.to_vec(), layer });
                    inner_count += 1;
                }
                inners.push(map);
            }
        } else {
            inners.resize_with(outer.tables.len(), InnerMap::new);
        }
        Self { params: params.clone(), outer, inners, n_local, inner_count }
    }

    /// Convenience: build all tables (single-core index).
    pub fn build_full<P: Points + ?Sized>(params: &SlshParams, points: &P) -> Self {
        let all: Vec<usize> = (0..params.outer.l).collect();
        Self::build(params, points, &all)
    }

    pub fn n_local(&self) -> usize {
        self.n_local
    }

    pub fn num_tables(&self) -> usize {
        self.outer.tables.len()
    }

    pub fn mem_bytes(&self) -> usize {
        self.outer.mem_bytes()
            + self
                .inners
                .iter()
                .flat_map(|m| m.values())
                .map(|i| i.layer.mem_bytes() + i.members.len() * 4)
                .sum::<usize>()
    }

    /// Gather the deduplicated candidate set for `q` across the owned
    /// tables ("the union of the datapoints which collide with the query",
    /// narrowed through inner layers where present).
    pub fn candidates(&self, q: &[f32], visited: &mut StampSet, out: &mut Vec<u32>) -> QueryStats {
        debug_assert!(visited.capacity() >= self.n_local);
        self.gather_with_keys(q, |pos| self.outer.tables[pos].hash.hash(q), visited, out)
    }

    /// Shared candidate-gathering body: `key_at(pos)` supplies table
    /// `pos`'s key for `q` — hashed on the spot by [`candidates`], read
    /// from the batch-hashed key block by [`query_batch`]. Keeping one
    /// body is what makes the two paths bit-identical by construction.
    ///
    /// [`candidates`]: SlshIndex::candidates
    /// [`query_batch`]: SlshIndex::query_batch
    fn gather_with_keys(
        &self,
        q: &[f32],
        mut key_at: impl FnMut(usize) -> PackedKey,
        visited: &mut StampSet,
        out: &mut Vec<u32>,
    ) -> QueryStats {
        let mut stats = QueryStats::default();
        out.clear();
        visited.clear();
        for pos in 0..self.outer.tables.len() {
            let key = key_at(pos);
            self.gather_table(pos, q, key, visited, out, &mut stats);
        }
        stats.tables = self.outer.tables.len() as u32;
        stats.comparisons = out.len() as u64;
        stats
    }

    /// Gather ONE owned table's (deduplicated) contribution to the
    /// candidate set — the per-table body shared by the all-tables walk
    /// above and the budget-enforced table-at-a-time walk in
    /// [`query_batch_cancel`](SlshIndex::query_batch_cancel), which is
    /// what makes an enforced answer an exact table-prefix of the
    /// unenforced one.
    fn gather_table(
        &self,
        pos: usize,
        q: &[f32],
        key: PackedKey,
        visited: &mut StampSet,
        out: &mut Vec<u32>,
        stats: &mut QueryStats,
    ) {
        let lt = &self.outer.tables[pos];
        let Some(bucket_idx) = lt.table.find_bucket(&key) else { return };
        let ids = lt.table.bucket(bucket_idx);
        if ids.is_empty() {
            return;
        }
        if let Some(inner) = self.inners[pos].get(&bucket_idx) {
            stats.inner_probes += 1;
            inner.layer.probe_each(q, |_t, positions| {
                for &p in positions {
                    let id = inner.members[p as usize];
                    if visited.insert(id) {
                        out.push(id);
                    }
                }
            });
        } else {
            stats.direct_buckets += 1;
            for &id in ids {
                if visited.insert(id) {
                    out.push(id);
                }
            }
        }
    }

    /// Resolve a query on this core: gather candidates, scan them with the
    /// engine (final ranking metric is the outer layer's l1, matching the
    /// PKNN baseline), return the partial top-K and stats.
    pub fn query(
        &self,
        engine: &dyn DistanceEngine,
        q: &[f32],
        data: &[f32],
        labels: &[bool],
        id_base: u64,
        visited: &mut StampSet,
        scratch: &mut Vec<u32>,
    ) -> QueryOutput {
        let stats = self.candidates(q, visited, scratch);
        let mut topk = TopK::new(self.params.k);
        let scanned = engine.scan(
            Metric::L1,
            q,
            data,
            self.params.outer.dim,
            scratch,
            labels,
            id_base,
            &mut topk,
        );
        debug_assert_eq!(scanned, stats.comparisons);
        QueryOutput { topk, stats }
    }

    /// Resolve a block of queries (`qs` row-major `nq × dim`) — the
    /// batched request path. Bit-identical to calling [`query`] once per
    /// row: hashing runs batched across all owned tables (one walk of
    /// each family's parameter arrays per tile), candidate gathering and
    /// the scan then reuse `scratch`'s visited set / candidate buffer /
    /// pooled top-Ks, and `out` is refilled in place. Steady state
    /// allocates nothing per query.
    ///
    /// [`query`]: SlshIndex::query
    #[allow(clippy::too_many_arguments)]
    pub fn query_batch(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        data: &[f32],
        labels: &[bool],
        id_base: u64,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
    ) {
        let dim = self.params.outer.dim;
        assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
        let nq = qs.len() / dim;
        scratch.ensure(self.n_local, nq, self.params.k);
        out.clear();
        // Stage 1 — batched hashing: every owned outer table hashes the
        // whole block in one pass ([table_pos * nq + query] layout).
        self.outer.hash_batch(qs, dim, &mut scratch.keys);
        // Stage 2 — per query: gather candidates through the same body
        // the sequential path uses (keys read from the batch block) and
        // scan them into a pooled top-K.
        let QueryScratch { visited, cand, keys, topks } = scratch;
        for qi in 0..nq {
            let q = &qs[qi * dim..(qi + 1) * dim];
            let stats = self.gather_with_keys(q, |pos| keys[pos * nq + qi], visited, cand);
            let topk = &mut topks[qi];
            topk.reset(self.params.k);
            let scanned = engine.scan(Metric::L1, q, data, dim, cand, labels, id_base, topk);
            debug_assert_eq!(scanned, stats.comparisons);
            out.push_query(topk, stats);
        }
    }

    /// Budget-enforced twin of [`query_batch`]: resolution proceeds
    /// table-at-a-time and *stops* — hashing, gathering and scanning —
    /// the moment `cancel`'s deadline is blown, instead of finishing the
    /// remaining tables late.
    ///
    /// Mechanics, chosen so partial answers have exact semantics:
    ///
    /// * **Lazy hashing** — owned tables are batch-hashed one table at a
    ///   time, on first use; tables past the stopping point are never
    ///   hashed at all.
    /// * **Table-at-a-time gather + scan** — each table's (deduplicated)
    ///   candidates are gathered and scanned before the next table is
    ///   touched, through the same per-table body the unenforced path
    ///   uses, with the deadline checked between tables and (inside
    ///   [`DistanceEngine::scan_until`]) between candidate tiles.
    /// * **Prefix contract** — a partial answer equals the *unenforced*
    ///   answer of an index holding only the first [`QueryStats::tables`]
    ///   owned tables, truncated to the first [`QueryStats::comparisons`]
    ///   candidates — a strict prefix of the full resolution, never a
    ///   sample (`rust/tests/budget_enforcement.rs` asserts this
    ///   reconstruction bit-for-bit).
    /// * **Batch-shared deadline** — one `cancel` covers the whole block;
    ///   once it trips, every later query in the block reports
    ///   `partial = true` with zero work, matching the node-level budget
    ///   (the batch, not each query, owns the deadline).
    ///
    /// With a deadline that never trips, results and stats are
    /// bit-identical to [`query_batch`] — same candidate order, same scan
    /// order, same counters.
    ///
    /// [`query_batch`]: SlshIndex::query_batch
    #[allow(clippy::too_many_arguments)]
    pub fn query_batch_cancel(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        data: &[f32],
        labels: &[bool],
        id_base: u64,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
        cancel: &ScanCancel,
    ) {
        let dim = self.params.outer.dim;
        assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
        let nq = qs.len() / dim;
        scratch.ensure(self.n_local, nq, self.params.k);
        out.clear();
        let QueryScratch { visited, cand, keys, topks } = scratch;
        keys.clear();
        let n_tables = self.outer.tables.len();
        // Tables hashed so far: the batch-hashed key block is extended
        // lazily, one table (all nq queries) at a time, preserving the
        // `keys[pos * nq + qi]` layout.
        let mut hashed = 0usize;
        for qi in 0..nq {
            let q = &qs[qi * dim..(qi + 1) * dim];
            let topk = &mut topks[qi];
            topk.reset(self.params.k);
            let mut stats = QueryStats::default();
            visited.clear();
            cand.clear();
            for pos in 0..n_tables {
                if cancel.blown() {
                    stats.partial = true;
                    break;
                }
                if hashed == pos {
                    self.outer.tables[pos].hash.hash_batch(qs, dim, keys);
                    hashed += 1;
                }
                let start = cand.len();
                self.gather_table(pos, q, keys[pos * nq + qi], visited, cand, &mut stats);
                stats.tables += 1;
                let fresh = cand.len() - start;
                let scanned = engine.scan_until(
                    Metric::L1,
                    q,
                    data,
                    dim,
                    &cand[start..],
                    labels,
                    id_base,
                    topk,
                    cancel,
                );
                stats.comparisons += scanned;
                if scanned < fresh as u64 {
                    stats.partial = true;
                    break;
                }
            }
            out.push_query(topk, stats);
        }
    }

    /// Knob-carrying entry point: resolve a block under a [`ProbeSpec`]
    /// (probes per table + candidate budget), optionally deadline-bounded.
    ///
    /// * `spec == ProbeSpec::BASELINE` dispatches to the *exact* legacy
    ///   path — [`query_batch`] (no `cancel`) or [`query_batch_cancel`]
    ///   (with one) — so the default spec is bit-identical to the
    ///   pre-multi-probe code by construction.
    /// * `probes = P > 1` visits, per owned table, the first `P` buckets
    ///   of the margin-ordered flip-≤2 probe sequence
    ///   ([`crate::lsh::probe`]); candidates dedupe through the same
    ///   visited set, so the candidate *set* grows monotonically with `P`.
    /// * `max_comparisons > 0` is a hard per-query candidate budget:
    ///   each table's fresh candidates are truncated so the running scan
    ///   count never exceeds the cap, then resolution stops with
    ///   `partial = true`. The cap is enforced by list truncation — no
    ///   clock involved — so a capped answer is bit-reproducible and
    ///   equals the uncapped candidate walk cut at exactly
    ///   `max_comparisons` candidates ([`candidates_spec`] reconstructs
    ///   it).
    ///
    /// [`query_batch`]: SlshIndex::query_batch
    /// [`query_batch_cancel`]: SlshIndex::query_batch_cancel
    /// [`candidates_spec`]: SlshIndex::candidates_spec
    #[allow(clippy::too_many_arguments)]
    pub fn query_batch_spec(
        &self,
        engine: &dyn DistanceEngine,
        qs: &[f32],
        data: &[f32],
        labels: &[bool],
        id_base: u64,
        spec: ProbeSpec,
        scratch: &mut QueryScratch,
        out: &mut BatchOutput,
        cancel: Option<&ScanCancel>,
    ) {
        if spec.is_baseline() {
            match cancel {
                None => self.query_batch(engine, qs, data, labels, id_base, scratch, out),
                Some(c) => {
                    self.query_batch_cancel(engine, qs, data, labels, id_base, scratch, out, c)
                }
            }
            return;
        }
        let dim = self.params.outer.dim;
        assert!(dim > 0 && qs.len() % dim == 0, "query block not a multiple of dim");
        let nq = qs.len() / dim;
        scratch.ensure(self.n_local, nq, self.params.k);
        out.clear();
        let QueryScratch { visited, cand, keys, topks, margins, probe_keys, probe } = scratch;
        keys.clear();
        let n_tables = self.outer.tables.len();
        let mut hashed = 0usize;
        for qi in 0..nq {
            let q = &qs[qi * dim..(qi + 1) * dim];
            let topk = &mut topks[qi];
            topk.reset(self.params.k);
            let mut stats = QueryStats::default();
            visited.clear();
            cand.clear();
            for pos in 0..n_tables {
                if cancel.is_some_and(|c| c.blown()) {
                    stats.partial = true;
                    break;
                }
                if hashed == pos {
                    self.outer.tables[pos].hash.hash_batch(qs, dim, keys);
                    hashed += 1;
                }
                let start = cand.len();
                let base = keys[pos * nq + qi];
                if spec.probes > 1 {
                    let hash = &self.outer.tables[pos].hash;
                    hash.margins(q, margins);
                    probe.generate(base, margins, spec.probes, probe_keys);
                    for &key in probe_keys.iter() {
                        self.gather_table(pos, q, key, visited, cand, &mut stats);
                    }
                } else {
                    self.gather_table(pos, q, base, visited, cand, &mut stats);
                }
                stats.tables += 1;
                let mut fresh = (cand.len() - start) as u64;
                let mut capped = false;
                if spec.max_comparisons > 0 {
                    let room = spec.max_comparisons.saturating_sub(stats.comparisons);
                    if fresh > room {
                        cand.truncate(start + room as usize);
                        fresh = room;
                        capped = true;
                    }
                }
                let scanned = match cancel {
                    None => {
                        engine.scan(Metric::L1, q, data, dim, &cand[start..], labels, id_base, topk)
                    }
                    Some(c) => engine.scan_until(
                        Metric::L1,
                        q,
                        data,
                        dim,
                        &cand[start..],
                        labels,
                        id_base,
                        topk,
                        c,
                    ),
                };
                stats.comparisons += scanned;
                if scanned < fresh || capped {
                    stats.partial = true;
                    break;
                }
            }
            out.push_query(topk, stats);
        }
    }

    /// Spec-aware twin of [`candidates`]: the deduplicated candidate list
    /// a [`query_batch_spec`] resolution scans, in scan order, with the
    /// `max_comparisons` truncation applied. Exists so tests (and
    /// debugging) can reconstruct a capped answer: scanning exactly this
    /// list with the engine reproduces the capped query bit-for-bit.
    ///
    /// [`candidates`]: SlshIndex::candidates
    /// [`query_batch_spec`]: SlshIndex::query_batch_spec
    pub fn candidates_spec(
        &self,
        q: &[f32],
        spec: ProbeSpec,
        scratch: &mut QueryScratch,
        out: &mut Vec<u32>,
    ) -> QueryStats {
        if spec.is_baseline() {
            scratch.visited.ensure_capacity(self.n_local);
            return self.candidates(q, &mut scratch.visited, out);
        }
        scratch.visited.ensure_capacity(self.n_local);
        let QueryScratch { visited, margins, probe_keys, probe, .. } = scratch;
        let mut stats = QueryStats::default();
        out.clear();
        visited.clear();
        for pos in 0..self.outer.tables.len() {
            let base = self.outer.tables[pos].hash.hash(q);
            if spec.probes > 1 {
                self.outer.tables[pos].hash.margins(q, margins);
                probe.generate(base, margins, spec.probes, probe_keys);
                for &key in probe_keys.iter() {
                    self.gather_table(pos, q, key, visited, out, &mut stats);
                }
            } else {
                self.gather_table(pos, q, base, visited, out, &mut stats);
            }
            stats.tables += 1;
            if spec.max_comparisons > 0 && out.len() as u64 > spec.max_comparisons {
                out.truncate(spec.max_comparisons as usize);
                stats.partial = true;
                break;
            }
        }
        stats.comparisons = out.len() as u64;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeEngine;
    use crate::knn::exhaustive::pknn_query;
    use crate::lsh::family::LayerSpec;
    use crate::slsh::params::InnerParams;
    use crate::util::rng::Xoshiro256;

    /// Clustered fixture shaped like the ABP windows: tight clusters with
    /// a handful of large "stable patient" clusters that dominate buckets.
    struct Fixture {
        data: Vec<f32>,
        labels: Vec<bool>,
        dim: usize,
    }

    impl Fixture {
        fn new(seed: u64) -> Self {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let dim = 30;
            let mut data = Vec::new();
            let mut labels = Vec::new();
            // 3 big clusters (60% of points) + 40 small ones.
            let mut add_cluster = |rng: &mut Xoshiro256, count: usize, positive: bool| {
                let center: Vec<f32> =
                    (0..dim).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
                for _ in 0..count {
                    for &c in &center {
                        data.push(c + rng.gen_normal(0.0, 0.5) as f32);
                    }
                    labels.push(positive);
                }
            };
            for _ in 0..3 {
                add_cluster(&mut rng, 400, false);
            }
            for i in 0..40 {
                add_cluster(&mut rng, 20, i % 8 == 0);
            }
            Self { data, labels, dim }
        }

        fn view(&self) -> SliceView<'_> {
            SliceView { data: &self.data, dim: self.dim }
        }

        fn n(&self) -> usize {
            self.labels.len()
        }
    }

    fn lsh_params(m: usize, l: usize, seed: u64) -> SlshParams {
        SlshParams::lsh_only(LayerSpec::outer_l1(30, m, l, 20.0, 180.0, seed), 10)
    }

    fn slsh_params(m: usize, l: usize, alpha: f64, seed: u64) -> SlshParams {
        SlshParams {
            outer: LayerSpec::outer_l1(30, m, l, 20.0, 180.0, seed),
            inner: Some(InnerParams { m: 24, l: 8, alpha, seed: seed ^ 0xABCD }),
            k: 10,
        }
    }

    #[test]
    fn alpha_one_reduces_to_plain_lsh() {
        let fx = Fixture::new(1);
        // alpha = 1.0 ⇒ no bucket exceeds the threshold ⇒ SLSH ≡ LSH.
        let lsh = SlshIndex::build_full(&lsh_params(24, 12, 7), &fx.view());
        let slsh = SlshIndex::build_full(&slsh_params(24, 12, 1.0, 7), &fx.view());
        assert_eq!(slsh.inner_count, 0);
        let mut visited = StampSet::new(fx.n());
        let mut a = Vec::new();
        let mut b = Vec::new();
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..30 {
            let q: Vec<f32> = (0..30).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
            lsh.candidates(&q, &mut visited, &mut a);
            slsh.candidates(&q, &mut visited, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn candidates_are_deduplicated_and_within_shard() {
        let fx = Fixture::new(3);
        let idx = SlshIndex::build_full(&lsh_params(20, 16, 11), &fx.view());
        let mut visited = StampSet::new(fx.n());
        let mut out = Vec::new();
        let q = fx.view().point(10).to_vec();
        let stats = idx.candidates(&q, &mut visited, &mut out);
        assert_eq!(stats.comparisons as usize, out.len());
        let mut sorted = out.clone();
        sorted.sort_unstable();
        let before = sorted.len();
        sorted.dedup();
        assert_eq!(sorted.len(), before, "duplicate candidates returned");
        assert!(out.iter().all(|&id| (id as usize) < fx.n()));
        // A point must be its own candidate.
        assert!(out.contains(&10));
    }

    #[test]
    fn inner_layer_builds_on_populous_buckets_and_cuts_candidates() {
        let fx = Fixture::new(4);
        // Coarse outer hash (small m) ⇒ the 400-point clusters form huge
        // buckets; alpha = 0.05 ⇒ threshold = 90 points.
        let lsh = SlshIndex::build_full(&lsh_params(12, 8, 13), &fx.view());
        let slsh = SlshIndex::build_full(&slsh_params(12, 8, 0.05, 13), &fx.view());
        assert!(slsh.inner_count > 0, "no inner indices built");
        let mut visited = StampSet::new(fx.n());
        let mut out = Vec::new();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let (mut total_lsh, mut total_slsh, mut inner_hits) = (0u64, 0u64, 0u64);
        for _ in 0..50 {
            // Queries near big-cluster members.
            let base = rng.gen_index(1200);
            let mut q = fx.view().point(base).to_vec();
            for v in q.iter_mut() {
                *v += rng.gen_normal(0.0, 0.3) as f32;
            }
            total_lsh += lsh.candidates(&q, &mut visited, &mut out).comparisons;
            let s = slsh.candidates(&q, &mut visited, &mut out);
            total_slsh += s.comparisons;
            inner_hits += s.inner_probes;
        }
        assert!(inner_hits > 0, "inner layer never probed");
        assert!(
            total_slsh < total_lsh,
            "stratification must reduce comparisons: slsh={total_slsh} lsh={total_lsh}"
        );
    }

    #[test]
    fn sharded_union_equals_full_index_candidates() {
        let fx = Fixture::new(6);
        let params = slsh_params(20, 12, 0.05, 17);
        let full = SlshIndex::build_full(&params, &fx.view());
        let p = 4;
        let shards: Vec<SlshIndex> = (0..p)
            .map(|core| {
                let mine: Vec<usize> = (0..12).filter(|t| t % p == core).collect();
                SlshIndex::build(&params, &fx.view(), &mine)
            })
            .collect();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut visited = StampSet::new(fx.n());
        let mut buf = Vec::new();
        for _ in 0..20 {
            let q: Vec<f32> = (0..30).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
            full.candidates(&q, &mut visited, &mut buf);
            let mut full_set: Vec<u32> = buf.clone();
            full_set.sort_unstable();
            let mut union: Vec<u32> = Vec::new();
            for s in &shards {
                s.candidates(&q, &mut visited, &mut buf);
                union.extend_from_slice(&buf);
            }
            union.sort_unstable();
            union.dedup();
            assert_eq!(union, full_set);
        }
    }

    #[test]
    fn query_ranks_by_l1_and_counts_comparisons() {
        let fx = Fixture::new(8);
        let idx = SlshIndex::build_full(&lsh_params(20, 16, 19), &fx.view());
        let engine = NativeEngine::new();
        let mut visited = StampSet::new(fx.n());
        let mut scratch = Vec::new();
        let q = fx.view().point(42).to_vec();
        let out = idx.query(&engine, &q, &fx.data, &fx.labels, 5000, &mut visited, &mut scratch);
        let nbs = out.topk.into_sorted();
        assert!(!nbs.is_empty());
        assert_eq!(nbs[0].id, 5042, "self must be nearest (id_base applied)");
        assert_eq!(nbs[0].dist, 0.0);
        assert!(nbs.windows(2).all(|w| w[0].dist <= w[1].dist));
        assert!(out.stats.comparisons > 0);
        assert!(out.stats.comparisons < fx.n() as u64, "must beat exhaustive");
    }

    #[test]
    fn recall_against_exhaustive_on_clustered_data() {
        let fx = Fixture::new(9);
        let idx = SlshIndex::build_full(&lsh_params(28, 24, 23), &fx.view());
        let engine = NativeEngine::new();
        let mut visited = StampSet::new(fx.n());
        let mut scratch = Vec::new();
        let mut rng = Xoshiro256::seed_from_u64(10);
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..40 {
            let base = rng.gen_index(fx.n());
            let mut q = fx.view().point(base).to_vec();
            for v in q.iter_mut() {
                *v += rng.gen_normal(0.0, 0.2) as f32;
            }
            let truth = pknn_query(&engine, Metric::L1, &q, &fx.data, 30, &fx.labels, 10, 1);
            let approx = idx
                .query(&engine, &q, &fx.data, &fx.labels, 0, &mut visited, &mut scratch)
                .topk
                .into_sorted();
            let truth_ids: std::collections::HashSet<u64> =
                truth.neighbors.iter().map(|n| n.id).collect();
            hits += approx.iter().filter(|n| truth_ids.contains(&n.id)).count();
            total += truth.neighbors.len();
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.6, "recall too low: {recall}");
    }

    #[test]
    fn query_batch_is_bit_identical_to_sequential_queries() {
        let fx = Fixture::new(14);
        let engine = NativeEngine::new();
        // LSH-only and stratified indices, batch sizes incl. 1 and
        // non-multiples of the hash/scan tiles.
        for params in [lsh_params(20, 16, 31), slsh_params(12, 8, 0.05, 31)] {
            let idx = SlshIndex::build_full(&params, &fx.view());
            let mut scratch = QueryScratch::new(fx.n());
            let mut out = BatchOutput::new();
            let mut visited = StampSet::new(fx.n());
            let mut cand = Vec::new();
            let mut rng = Xoshiro256::seed_from_u64(15);
            for nq in [1usize, 3, 5, 9] {
                let qs: Vec<f32> =
                    (0..nq * 30).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
                idx.query_batch(&engine, &qs, &fx.data, &fx.labels, 700, &mut scratch, &mut out);
                assert_eq!(out.len(), nq);
                for qi in 0..nq {
                    let seq = idx.query(
                        &engine,
                        &qs[qi * 30..(qi + 1) * 30],
                        &fx.data,
                        &fx.labels,
                        700,
                        &mut visited,
                        &mut cand,
                    );
                    assert_eq!(out.stats(qi), seq.stats, "nq={nq} qi={qi}");
                    // Bit-identical neighbors (Neighbor: PartialEq compares
                    // the f32 distance exactly).
                    assert_eq!(out.neighbors(qi), seq.topk.into_sorted().as_slice());
                }
            }
        }
    }

    #[test]
    fn query_batch_cancel_unbounded_is_bit_identical_to_query_batch() {
        use crate::util::clock::MockClock;
        let fx = Fixture::new(14);
        let engine = NativeEngine::new();
        for params in [lsh_params(20, 16, 31), slsh_params(12, 8, 0.05, 31)] {
            let idx = SlshIndex::build_full(&params, &fx.view());
            let mut scratch = QueryScratch::new(fx.n());
            let mut plain = BatchOutput::new();
            let mut enforced = BatchOutput::new();
            let cancel = ScanCancel::unbounded(std::sync::Arc::new(MockClock::new(0)));
            let mut rng = Xoshiro256::seed_from_u64(16);
            for nq in [1usize, 3, 9] {
                let qs: Vec<f32> =
                    (0..nq * 30).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
                idx.query_batch(&engine, &qs, &fx.data, &fx.labels, 70, &mut scratch, &mut plain);
                idx.query_batch_cancel(
                    &engine,
                    &qs,
                    &fx.data,
                    &fx.labels,
                    70,
                    &mut scratch,
                    &mut enforced,
                    &cancel,
                );
                assert_eq!(enforced.len(), nq);
                for qi in 0..nq {
                    assert_eq!(enforced.stats(qi), plain.stats(qi), "nq={nq} qi={qi}");
                    assert!(!enforced.stats(qi).partial);
                    assert_eq!(enforced.stats(qi).tables, idx.num_tables() as u32);
                    assert_eq!(enforced.neighbors(qi), plain.neighbors(qi), "nq={nq} qi={qi}");
                }
            }
        }
    }

    #[test]
    fn query_batch_cancel_blown_deadline_does_no_work() {
        use crate::util::clock::MockClock;
        let fx = Fixture::new(15);
        let engine = NativeEngine::new();
        let idx = SlshIndex::build_full(&lsh_params(20, 16, 31), &fx.view());
        let mut scratch = QueryScratch::new(fx.n());
        let mut out = BatchOutput::new();
        // Deadline already passed: every query must come back partial,
        // with zero tables consulted and zero comparisons.
        let cancel = ScanCancel::until(std::sync::Arc::new(MockClock::new(1000)), 1000);
        let qs: Vec<f32> = (0..3 * 30).map(|i| 40.0 + (i % 30) as f32).collect();
        idx.query_batch_cancel(
            &engine,
            &qs,
            &fx.data,
            &fx.labels,
            0,
            &mut scratch,
            &mut out,
            &cancel,
        );
        assert_eq!(out.len(), 3);
        for qi in 0..3 {
            let st = out.stats(qi);
            assert!(st.partial, "qi={qi}");
            assert_eq!(st.tables, 0);
            assert_eq!(st.comparisons, 0);
            assert!(out.neighbors(qi).is_empty());
        }
    }

    #[test]
    fn baseline_spec_is_bit_identical_to_legacy_paths() {
        use crate::util::clock::MockClock;
        let fx = Fixture::new(14);
        let engine = NativeEngine::new();
        for params in [lsh_params(20, 16, 31), slsh_params(12, 8, 0.05, 31)] {
            let idx = SlshIndex::build_full(&params, &fx.view());
            let mut scratch = QueryScratch::new(fx.n());
            let mut plain = BatchOutput::new();
            let mut spec_out = BatchOutput::new();
            let mut rng = Xoshiro256::seed_from_u64(40);
            let qs: Vec<f32> = (0..5 * 30).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
            idx.query_batch(&engine, &qs, &fx.data, &fx.labels, 70, &mut scratch, &mut plain);
            idx.query_batch_spec(
                &engine,
                &qs,
                &fx.data,
                &fx.labels,
                70,
                ProbeSpec::BASELINE,
                &mut scratch,
                &mut spec_out,
                None,
            );
            for qi in 0..5 {
                assert_eq!(spec_out.stats(qi), plain.stats(qi));
                assert_eq!(spec_out.neighbors(qi), plain.neighbors(qi));
            }
            // And through the cancel arm with an unbounded deadline.
            let cancel = ScanCancel::unbounded(std::sync::Arc::new(MockClock::new(0)));
            idx.query_batch_spec(
                &engine,
                &qs,
                &fx.data,
                &fx.labels,
                70,
                ProbeSpec::BASELINE,
                &mut scratch,
                &mut spec_out,
                Some(&cancel),
            );
            for qi in 0..5 {
                assert_eq!(spec_out.stats(qi), plain.stats(qi));
                assert_eq!(spec_out.neighbors(qi), plain.neighbors(qi));
            }
        }
    }

    #[test]
    fn candidate_sets_grow_monotonically_with_probes() {
        let fx = Fixture::new(16);
        let idx = SlshIndex::build_full(&lsh_params(14, 8, 37), &fx.view());
        let mut scratch = QueryScratch::new(fx.n());
        let mut rng = Xoshiro256::seed_from_u64(41);
        let mut grew_somewhere = false;
        for _ in 0..20 {
            let q: Vec<f32> = (0..30).map(|_| rng.gen_f64(40.0, 140.0) as f32).collect();
            let mut prev: Option<std::collections::HashSet<u32>> = None;
            let mut prev_n = 0usize;
            for probes in [1u32, 2, 4, 8] {
                let mut cand = Vec::new();
                let stats =
                    idx.candidates_spec(&q, ProbeSpec::new(probes, 0), &mut scratch, &mut cand);
                assert_eq!(stats.comparisons as usize, cand.len());
                let set: std::collections::HashSet<u32> = cand.iter().copied().collect();
                assert_eq!(set.len(), cand.len(), "duplicates at P={probes}");
                if let Some(p) = &prev {
                    assert!(p.is_subset(&set), "candidate set shrank at P={probes}");
                    if set.len() > prev_n {
                        grew_somewhere = true;
                    }
                }
                prev_n = set.len();
                prev = Some(set);
            }
        }
        assert!(grew_somewhere, "multi-probe never found an extra candidate");
    }

    #[test]
    fn probes_one_candidates_spec_matches_candidates() {
        let fx = Fixture::new(17);
        let idx = SlshIndex::build_full(&lsh_params(20, 12, 39), &fx.view());
        let mut scratch = QueryScratch::new(fx.n());
        let mut visited = StampSet::new(fx.n());
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let q = fx.view().point(5).to_vec();
        let sa = idx.candidates(&q, &mut visited, &mut a);
        let sb = idx.candidates_spec(&q, ProbeSpec::BASELINE, &mut scratch, &mut b);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn max_comparisons_cap_is_a_reconstructible_prefix() {
        let fx = Fixture::new(18);
        let engine = NativeEngine::new();
        let idx = SlshIndex::build_full(&slsh_params(12, 8, 0.05, 43), &fx.view());
        let mut scratch = QueryScratch::new(fx.n());
        let mut out = BatchOutput::new();
        let q = fx.view().point(100).to_vec();
        // Uncapped comparison volume at P=4.
        let mut full = Vec::new();
        let full_stats =
            idx.candidates_spec(&q, ProbeSpec::new(4, 0), &mut scratch, &mut full);
        assert!(full_stats.comparisons > 32, "fixture too sparse for a cap test");
        let cap = full_stats.comparisons / 2;
        let spec = ProbeSpec::new(4, cap);
        // Capped candidates are the exact prefix of the uncapped walk.
        let mut capped = Vec::new();
        let capped_stats = idx.candidates_spec(&q, spec, &mut scratch, &mut capped);
        assert!(capped_stats.partial);
        assert_eq!(capped_stats.comparisons, cap);
        assert_eq!(capped[..], full[..cap as usize]);
        // And the capped query equals scanning exactly that prefix.
        idx.query_batch_spec(
            &engine,
            &q,
            &fx.data,
            &fx.labels,
            0,
            spec,
            &mut scratch,
            &mut out,
            None,
        );
        assert_eq!(out.stats(0).comparisons, cap);
        assert!(out.stats(0).partial);
        let mut topk = TopK::new(idx.params.k);
        let scanned =
            engine.scan(Metric::L1, &q, &fx.data, 30, &capped, &fx.labels, 0, &mut topk);
        assert_eq!(scanned, cap);
        assert_eq!(out.neighbors(0), topk.into_sorted().as_slice());
        // Deterministic: a second capped run is bit-identical.
        let mut again = BatchOutput::new();
        idx.query_batch_spec(
            &engine,
            &q,
            &fx.data,
            &fx.labels,
            0,
            spec,
            &mut scratch,
            &mut again,
            None,
        );
        assert_eq!(again.neighbors(0), out.neighbors(0));
        assert_eq!(again.stats(0), out.stats(0));
    }

    #[test]
    fn stats_bucket_kind_accounting() {
        let fx = Fixture::new(12);
        let slsh = SlshIndex::build_full(&slsh_params(12, 8, 0.05, 29), &fx.view());
        let mut visited = StampSet::new(fx.n());
        let mut out = Vec::new();
        let q = fx.view().point(0).to_vec(); // big-cluster member
        let stats = slsh.candidates(&q, &mut visited, &mut out);
        assert_eq!(stats.inner_probes + stats.direct_buckets as u64 > 0, true);
        assert!(stats.inner_probes + stats.direct_buckets <= 8);
    }
}
