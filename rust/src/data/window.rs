//! Rolling-window dataset extraction (paper §4, first paragraph).
//!
//! A datapoint is a time series spanning a *lag window* of length `l`
//! minutes, divided into `d = 30` subwindows; each sample is the mean MAP
//! of the **valid** heart beats in that subwindow. The point is labeled
//! positive iff an Acute Hypotensive Episode (AHE) occurs in the
//! *condition window* of length `c` minutes immediately following the lag
//! window, where AHE = "a c-minute interval in which at least 90% of the
//! per-beat MAP values are below 60 mmHg".
//!
//! The rolling algorithm moves the window by 10% of the total window size
//! `(l + c)` when no AHE is present, and jumps immediately past the
//! previous window when an AHE is present — reproducing the class balance
//! of Table 1.
//!
//! For efficiency the record is first aggregated to a per-second series
//! with prefix sums, making every window O(d) regardless of record length.

use crate::data::beats::{assess, BeatFlag, ValidityConfig};
use crate::data::waveform::Beat;

/// Specification of a windowed AHE-prediction dataset (Table 1 row).
#[derive(Debug, Clone, PartialEq)]
pub struct WindowSpec {
    /// Human-readable name, e.g. "AHE-301-30c".
    pub name: String,
    /// Lag window length in minutes (`l`).
    pub lag_min: f64,
    /// Number of subwindows (`d`); each sample covers `l/d` minutes.
    pub d: usize,
    /// Condition window length in minutes (`c`).
    pub cond_min: f64,
    /// Stride as a fraction of `(l + c)` when no AHE is found.
    pub stride_frac: f64,
    /// AHE definition: fraction of per-beat MAPs that must be low.
    pub ahe_low_frac: f64,
    /// AHE definition: hypotension threshold (mmHg).
    pub ahe_thresh: f32,
    /// Minimum fraction of subwindows that must contain at least one valid
    /// beat for the window to be usable (gap tolerance).
    pub min_covered_frac: f64,
}

impl WindowSpec {
    /// Paper dataset AHE-301-30c: l = 30 min, l/d = 1 min, c = 30 min.
    pub fn ahe_301_30c() -> Self {
        Self {
            name: "AHE-301-30c".into(),
            lag_min: 30.0,
            d: 30,
            cond_min: 30.0,
            stride_frac: 0.1,
            ahe_low_frac: 0.9,
            ahe_thresh: 60.0,
            min_covered_frac: 0.8,
        }
    }

    /// Paper dataset AHE-51-5c: l = 5 min, l/d = 10 s, c = 5 min.
    pub fn ahe_51_5c() -> Self {
        Self {
            name: "AHE-51-5c".into(),
            lag_min: 5.0,
            d: 30,
            cond_min: 5.0,
            stride_frac: 0.1,
            ahe_low_frac: 0.9,
            ahe_thresh: 60.0,
            min_covered_frac: 0.8,
        }
    }

    /// Kim et al. [10, 11] configuration (for the Table 1 reference row).
    pub fn kim_2016() -> Self {
        Self {
            name: "Kim-301-30c".into(),
            lag_min: 300.0,
            d: 300,
            cond_min: 30.0,
            stride_frac: 0.1,
            ahe_low_frac: 0.9,
            ahe_thresh: 60.0,
            min_covered_frac: 0.8,
        }
    }

    pub fn lag_s(&self) -> f64 {
        self.lag_min * 60.0
    }
    pub fn cond_s(&self) -> f64 {
        self.cond_min * 60.0
    }
    pub fn total_s(&self) -> f64 {
        self.lag_s() + self.cond_s()
    }
    pub fn stride_s(&self) -> f64 {
        (self.total_s() * self.stride_frac).max(1.0)
    }
    pub fn subwindow_s(&self) -> f64 {
        self.lag_s() / self.d as f64
    }
}

/// Per-second aggregation of a record's valid beats, with prefix sums for
/// O(1) range queries.
#[derive(Debug, Clone)]
pub struct SecondsSeries {
    /// prefix_map[i] = Σ MAP of valid beats in seconds [0, i).
    prefix_map: Vec<f64>,
    /// prefix_valid[i] = # valid beats in seconds [0, i).
    prefix_valid: Vec<u32>,
    /// prefix_low[i] = # valid beats with MAP < thresh in seconds [0, i).
    prefix_low: Vec<u32>,
    /// Hypotension threshold the low counter was built with.
    pub thresh: f32,
}

impl SecondsSeries {
    /// Aggregate a record: validity per beat, then per-second sums.
    pub fn build(beats: &[Beat], validity: &ValidityConfig, thresh: f32) -> Self {
        let total_s = beats.last().map(|b| b.t as usize + 1).unwrap_or(0);
        let flags = assess(beats, validity);
        let mut map_sum = vec![0f64; total_s];
        let mut valid = vec![0u32; total_s];
        let mut low = vec![0u32; total_s];
        for (b, f) in beats.iter().zip(&flags) {
            if *f != BeatFlag::Valid {
                continue;
            }
            let s = b.t as usize;
            let m = b.map();
            map_sum[s] += m as f64;
            valid[s] += 1;
            if m < thresh {
                low[s] += 1;
            }
        }
        // Prefix sums (length total_s + 1).
        let mut prefix_map = vec![0f64; total_s + 1];
        let mut prefix_valid = vec![0u32; total_s + 1];
        let mut prefix_low = vec![0u32; total_s + 1];
        for i in 0..total_s {
            prefix_map[i + 1] = prefix_map[i] + map_sum[i];
            prefix_valid[i + 1] = prefix_valid[i] + valid[i];
            prefix_low[i + 1] = prefix_low[i] + low[i];
        }
        Self { prefix_map, prefix_valid, prefix_low, thresh }
    }

    /// Record length in whole seconds.
    pub fn len_s(&self) -> usize {
        self.prefix_map.len() - 1
    }

    /// (sum of MAPs, count of valid beats) in seconds `[a, b)`, clamped.
    fn range(&self, a: usize, b: usize) -> (f64, u32) {
        let b = b.min(self.len_s());
        let a = a.min(b);
        (
            self.prefix_map[b] - self.prefix_map[a],
            self.prefix_valid[b] - self.prefix_valid[a],
        )
    }

    fn range_low(&self, a: usize, b: usize) -> (u32, u32) {
        let b = b.min(self.len_s());
        let a = a.min(b);
        (
            self.prefix_low[b] - self.prefix_low[a],
            self.prefix_valid[b] - self.prefix_valid[a],
        )
    }

    /// Mean MAP of valid beats in `[a, b)` seconds, or None if empty.
    pub fn mean_map(&self, a: usize, b: usize) -> Option<f32> {
        let (sum, count) = self.range(a, b);
        if count == 0 {
            None
        } else {
            Some((sum / count as f64) as f32)
        }
    }

    /// AHE test over `[a, b)` seconds: at least `low_frac` of the valid
    /// per-beat MAPs below the threshold (and a sane minimum beat count so
    /// empty stretches don't count as episodes).
    pub fn is_ahe(&self, a: usize, b: usize, low_frac: f64) -> bool {
        let (low, total) = self.range_low(a, b);
        let span = b.saturating_sub(a).max(1);
        // Require ≥ 0.2 valid beats/second on average (HR ≥ 12 bpm) —
        // guards against labeling signal-loss gaps as hypotension.
        if (total as f64) < span as f64 * 0.2 {
            return false;
        }
        low as f64 >= low_frac * total as f64
    }
}

/// One extracted datapoint.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowPoint {
    /// `d` subwindow mean-MAP samples.
    pub series: Vec<f32>,
    /// AHE occurred in the condition window.
    pub label: bool,
    /// Lag-window start time (seconds) — kept for traceability.
    pub t_start: f64,
}

/// Apply the rolling-window algorithm to one record.
pub fn extract_windows(series: &SecondsSeries, spec: &WindowSpec) -> Vec<WindowPoint> {
    let lag = spec.lag_s() as usize;
    let cond = spec.cond_s() as usize;
    let total = lag + cond;
    let stride = spec.stride_s() as usize;
    let sub = spec.subwindow_s();
    let mut out = Vec::new();
    if series.len_s() < total {
        return out;
    }
    let mut start = 0usize;
    while start + total <= series.len_s() {
        // Subwindow means over the lag window.
        let mut samples = Vec::with_capacity(spec.d);
        let mut covered = 0usize;
        for k in 0..spec.d {
            let a = start + (k as f64 * sub) as usize;
            let b = start + (((k + 1) as f64) * sub) as usize;
            match series.mean_map(a, b.max(a + 1)) {
                Some(m) => {
                    samples.push(m);
                    covered += 1;
                }
                None => samples.push(f32::NAN), // filled below if tolerable
            }
        }
        let usable = covered as f64 >= spec.min_covered_frac * spec.d as f64;
        let label = series.is_ahe(start + lag, start + total, spec.ahe_low_frac);
        if usable {
            // Fill gaps by nearest previous (then next) valid sample so
            // points are dense vectors — LSH needs complete coordinates.
            fill_gaps(&mut samples);
            out.push(WindowPoint { series: samples, label, t_start: start as f64 });
        }
        // Rolling rule from the paper.
        start += if label { total } else { stride };
    }
    out
}

/// Replace NaNs with the nearest valid neighbor (forward fill, then
/// backward fill for a leading gap).
fn fill_gaps(xs: &mut [f32]) {
    let mut last: Option<f32> = None;
    for x in xs.iter_mut() {
        if x.is_nan() {
            if let Some(v) = last {
                *x = v;
            }
        } else {
            last = Some(*x);
        }
    }
    let mut next: Option<f32> = None;
    for x in xs.iter_mut().rev() {
        if x.is_nan() {
            if let Some(v) = next {
                *x = v;
            }
        } else {
            next = Some(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::waveform::{generate_record, WaveformConfig};
    use crate::util::rng::Xoshiro256;

    /// Deterministic sub-mmHg jitter so synthetic beats are not rejected
    /// by the (correct) flatline detector.
    fn jitter(s: usize) -> f32 {
        ((s * 7919) % 13) as f32 * 0.01 - 0.06
    }

    /// Near-constant-MAP synthetic seconds series without the beat model.
    fn flat_series(len_s: usize, map: f32) -> SecondsSeries {
        let beats: Vec<Beat> = (0..len_s)
            .map(|s| {
                let m = map + jitter(s);
                Beat { t: s as f64 + 0.1, sbp: m + 14.0, dbp: m - 7.0 }
            })
            .collect();
        SecondsSeries::build(&beats, &ValidityConfig::default(), 60.0)
    }

    /// Series that is healthy then hypotensive from `drop_at` seconds on.
    fn dropping_series(len_s: usize, drop_at: usize) -> SecondsSeries {
        let beats: Vec<Beat> = (0..len_s)
            .map(|s| {
                // Gradual 60-second transition to avoid DeltaJump flags.
                let frac = ((s as f64 - drop_at as f64) / 60.0).clamp(0.0, 1.0) as f32;
                let map = 90.0 - frac * 45.0 + jitter(s); // 90 → 45 mmHg
                Beat { t: s as f64 + 0.1, sbp: map + 14.0, dbp: map - 7.0 }
            })
            .collect();
        SecondsSeries::build(&beats, &ValidityConfig::default(), 60.0)
    }

    #[test]
    fn seconds_series_prefix_sums() {
        let s = flat_series(100, 90.0);
        assert_eq!(s.len_s(), 100);
        let m = s.mean_map(10, 20).unwrap();
        assert!((m - 90.0).abs() < 0.1, "m={m}");
        assert!(s.mean_map(100, 110).is_none());
        assert!(!s.is_ahe(0, 100, 0.9));
    }

    #[test]
    fn ahe_detection_on_dropping_series() {
        let s = dropping_series(600, 100);
        // After 160 s everything is at MAP 45 < 60.
        assert!(s.is_ahe(200, 500, 0.9));
        assert!(!s.is_ahe(0, 90, 0.9));
    }

    #[test]
    fn empty_interval_is_not_ahe() {
        // Sparse beats (one per 10 s => 0.1 beats/s < 0.2 floor).
        let beats: Vec<Beat> = (0..60)
            .map(|i| Beat { t: i as f64 * 10.0, sbp: 55.0, dbp: 40.0 })
            .collect();
        let s = SecondsSeries::build(&beats, &ValidityConfig::default(), 60.0);
        assert!(!s.is_ahe(0, 600, 0.9), "sparse data must not label AHE");
    }

    #[test]
    fn window_extraction_counts_and_labels() {
        let spec = WindowSpec::ahe_51_5c();
        // 2 hours healthy: every window negative, strided by 1 min.
        let s = flat_series(7200, 90.0);
        let pts = extract_windows(&s, &spec);
        // (7200 - 600) / 60 + 1 = 111 windows.
        assert_eq!(pts.len(), 111);
        assert!(pts.iter().all(|p| !p.label));
        assert!(pts.iter().all(|p| p.series.len() == 30));
        assert!(pts
            .iter()
            .all(|p| p.series.iter().all(|x| (x - 90.0).abs() < 0.5)));
    }

    #[test]
    fn positive_windows_jump_past() {
        let spec = WindowSpec::ahe_51_5c();
        // Hypotensive from t=1000s to end of a 4000 s record.
        let s = dropping_series(4000, 1000);
        let pts = extract_windows(&s, &spec);
        let positives: Vec<&WindowPoint> = pts.iter().filter(|p| p.label).collect();
        assert!(!positives.is_empty(), "expected positive windows");
        // After each positive, next start is at least total window later.
        for w in pts.windows(2) {
            if w[0].label {
                assert!(
                    w[1].t_start - w[0].t_start >= spec.total_s() - 1.0,
                    "jump rule violated: {} -> {}",
                    w[0].t_start,
                    w[1].t_start
                );
            }
        }
        // Positive windows' lag series must show the decline (low tail).
        for p in positives {
            let tail = p.series[29];
            let head = p.series[0];
            assert!(
                tail <= head + 0.2,
                "expected non-increasing MAP in pre-AHE window (head={head}, tail={tail})"
            );
        }
    }

    #[test]
    fn stride_is_10pct_of_total() {
        let spec = WindowSpec::ahe_301_30c();
        assert!((spec.stride_s() - 360.0).abs() < 1e-9);
        let spec2 = WindowSpec::ahe_51_5c();
        assert!((spec2.stride_s() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn gap_fill_produces_dense_vectors() {
        let mut xs = vec![f32::NAN, 2.0, f32::NAN, f32::NAN, 5.0, f32::NAN];
        fill_gaps(&mut xs);
        assert_eq!(xs, vec![2.0, 2.0, 2.0, 2.0, 5.0, 5.0]);
    }

    #[test]
    fn short_record_yields_nothing() {
        let spec = WindowSpec::ahe_301_30c();
        let s = flat_series(600, 90.0); // 10 min < 60 min total
        assert!(extract_windows(&s, &spec).is_empty());
    }

    #[test]
    fn end_to_end_on_generated_record() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let cfg = WaveformConfig {
            record_hours: (12.0, 12.0),
            episodes_per_day: 6.0,
            ..Default::default()
        };
        let beats = generate_record(&cfg, &mut rng);
        let series = SecondsSeries::build(&beats, &ValidityConfig::default(), 60.0);
        let spec = WindowSpec::ahe_51_5c();
        let pts = extract_windows(&series, &spec);
        assert!(pts.len() > 200, "got {}", pts.len());
        let pos = pts.iter().filter(|p| p.label).count();
        // Episodes at 3/day over 10h: expect a few positives, massively
        // outnumbered by negatives.
        assert!(pos > 0, "no positive windows generated");
        assert!((pos as f64) < pts.len() as f64 * 0.35, "pos={pos}/{}", pts.len());
        // All points dense and in physiological range.
        for p in &pts {
            assert!(p.series.iter().all(|x| x.is_finite() && *x > 15.0 && *x < 185.0));
        }
    }
}
