//! Beat validity assessment — the beatDB v3 stand-in (Rivera 2017, [15] in
//! the paper). The paper: "Beat validity is assessed by checking whether
//! each beat respects a set of properties." We implement the standard
//! per-beat plausibility battery used by beatDB-style pipelines for ABP:
//! physiological ranges, pulse-pressure sanity, inter-beat interval limits,
//! jump (delta) limits against the previous valid beat, and flatline runs.

use crate::data::waveform::Beat;

/// Reason a beat was rejected (first failing check wins, ordered roughly
/// by severity). Kept as a dense enum so QC reports can histogram causes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BeatFlag {
    Valid,
    /// SBP outside [30, 250] mmHg or DBP outside [15, 200] mmHg.
    PressureRange,
    /// SBP − DBP outside [10, 120] mmHg.
    PulsePressure,
    /// Inter-beat interval outside [60/200, 60/25] seconds.
    BeatInterval,
    /// |ΔMAP| from the previous valid beat above 25 mmHg.
    DeltaJump,
    /// Part of a run of ≥ `FLATLINE_RUN` beats with identical pressures.
    Flatline,
}

/// Validity thresholds. Defaults follow common ABP QC practice
/// (e.g. Sun et al. 2006 / beatDB): they are deliberately permissive so
/// genuine hypotension (MAP down to ~25 mmHg) is NOT rejected.
#[derive(Debug, Clone)]
pub struct ValidityConfig {
    pub sbp_range: (f32, f32),
    pub dbp_range: (f32, f32),
    pub pulse_range: (f32, f32),
    /// Allowed inter-beat interval in seconds (HR 25–200 bpm).
    pub interval_range: (f64, f64),
    /// Max |MAP(t) − MAP(prev valid)| in mmHg.
    pub max_map_jump: f32,
    /// Minimum identical-pressure run length flagged as flatline.
    pub flatline_run: usize,
}

impl Default for ValidityConfig {
    fn default() -> Self {
        Self {
            sbp_range: (30.0, 250.0),
            dbp_range: (15.0, 200.0),
            pulse_range: (10.0, 120.0),
            interval_range: (60.0 / 200.0, 60.0 / 25.0),
            max_map_jump: 25.0,
            flatline_run: 5,
        }
    }
}

/// Classify every beat in a record. Returns one flag per beat.
pub fn assess(beats: &[Beat], cfg: &ValidityConfig) -> Vec<BeatFlag> {
    let mut flags = vec![BeatFlag::Valid; beats.len()];

    // Pass 1: flatline runs (identical SBP & DBP repeated).
    let mut run_start = 0;
    for i in 1..=beats.len() {
        let same = i < beats.len()
            && beats[i].sbp == beats[run_start].sbp
            && beats[i].dbp == beats[run_start].dbp;
        if !same {
            if i - run_start >= cfg.flatline_run {
                for f in flags.iter_mut().take(i).skip(run_start) {
                    *f = BeatFlag::Flatline;
                }
            }
            run_start = i;
        }
    }

    // Pass 2: per-beat checks + delta against last valid.
    let mut last_valid_map: Option<f32> = None;
    let mut last_t: Option<f64> = None;
    for (i, b) in beats.iter().enumerate() {
        if flags[i] == BeatFlag::Flatline {
            last_t = Some(b.t);
            continue;
        }
        let flag = check_one(b, last_valid_map, last_t, cfg);
        flags[i] = flag;
        if flag == BeatFlag::Valid {
            last_valid_map = Some(b.map());
        }
        last_t = Some(b.t);
    }
    flags
}

fn check_one(
    b: &Beat,
    last_valid_map: Option<f32>,
    last_t: Option<f64>,
    cfg: &ValidityConfig,
) -> BeatFlag {
    if b.sbp < cfg.sbp_range.0
        || b.sbp > cfg.sbp_range.1
        || b.dbp < cfg.dbp_range.0
        || b.dbp > cfg.dbp_range.1
    {
        return BeatFlag::PressureRange;
    }
    let pulse = b.sbp - b.dbp;
    if pulse < cfg.pulse_range.0 || pulse > cfg.pulse_range.1 {
        return BeatFlag::PulsePressure;
    }
    if let Some(prev_t) = last_t {
        let dt = b.t - prev_t;
        if dt < cfg.interval_range.0 || dt > cfg.interval_range.1 {
            return BeatFlag::BeatInterval;
        }
    }
    if let Some(prev_map) = last_valid_map {
        if (b.map() - prev_map).abs() > cfg.max_map_jump {
            return BeatFlag::DeltaJump;
        }
    }
    BeatFlag::Valid
}

/// QC summary over a record: counts per rejection cause.
#[derive(Debug, Clone, Default)]
pub struct QcReport {
    pub total: usize,
    pub valid: usize,
    pub pressure_range: usize,
    pub pulse_pressure: usize,
    pub beat_interval: usize,
    pub delta_jump: usize,
    pub flatline: usize,
}

impl QcReport {
    pub fn from_flags(flags: &[BeatFlag]) -> Self {
        let mut r = QcReport { total: flags.len(), ..Default::default() };
        for f in flags {
            match f {
                BeatFlag::Valid => r.valid += 1,
                BeatFlag::PressureRange => r.pressure_range += 1,
                BeatFlag::PulsePressure => r.pulse_pressure += 1,
                BeatFlag::BeatInterval => r.beat_interval += 1,
                BeatFlag::DeltaJump => r.delta_jump += 1,
                BeatFlag::Flatline => r.flatline += 1,
            }
        }
        r
    }

    pub fn valid_fraction(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.valid as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::waveform::{generate_record, WaveformConfig};
    use crate::util::rng::Xoshiro256;

    fn beat(t: f64, sbp: f32, dbp: f32) -> Beat {
        Beat { t, sbp, dbp }
    }

    /// A plausible healthy run to embed anomalies into.
    fn healthy(n: usize) -> Vec<Beat> {
        (0..n)
            .map(|i| beat(i as f64 * 0.8, 120.0 + (i % 3) as f32, 78.0 + (i % 2) as f32))
            .collect()
    }

    #[test]
    fn healthy_run_is_all_valid() {
        let flags = assess(&healthy(50), &ValidityConfig::default());
        assert!(flags.iter().all(|f| *f == BeatFlag::Valid), "{flags:?}");
    }

    #[test]
    fn range_violations_flagged() {
        let mut beats = healthy(10);
        beats[4] = beat(beats[4].t, 300.0, 150.0); // spike
        beats[7] = beat(beats[7].t, 10.0, 5.0); // dropout
        let flags = assess(&beats, &ValidityConfig::default());
        assert_eq!(flags[4], BeatFlag::PressureRange);
        assert_eq!(flags[7], BeatFlag::PressureRange);
        assert_eq!(flags[3], BeatFlag::Valid);
    }

    #[test]
    fn pulse_pressure_check() {
        let mut beats = healthy(10);
        beats[5] = beat(beats[5].t, 100.0, 95.0); // pulse = 5 < 10
        let flags = assess(&beats, &ValidityConfig::default());
        assert_eq!(flags[5], BeatFlag::PulsePressure);
    }

    #[test]
    fn interval_check() {
        let mut beats = healthy(10);
        // Insert a beat 0.05 s after the previous one (HR 1200 bpm).
        beats[6].t = beats[5].t + 0.05;
        let flags = assess(&beats, &ValidityConfig::default());
        assert_eq!(flags[6], BeatFlag::BeatInterval);
    }

    #[test]
    fn delta_jump_check_relative_to_last_valid() {
        let mut beats = healthy(10);
        // Sudden +40 mmHg jump in otherwise-plausible ranges.
        beats[8] = beat(beats[8].t, 170.0, 120.0);
        let flags = assess(&beats, &ValidityConfig::default());
        assert_eq!(flags[8], BeatFlag::DeltaJump);
        // And the next normal beat is judged against the last VALID map,
        // so it stays valid.
        assert_eq!(flags[9], BeatFlag::Valid);
    }

    #[test]
    fn flatline_detection_exact_run() {
        let mut beats = healthy(20);
        for b in beats.iter_mut().skip(5).take(6) {
            *b = beat(b.t, 90.0, 60.0);
        }
        let flags = assess(&beats, &ValidityConfig::default());
        for (i, f) in flags.iter().enumerate().skip(5).take(6) {
            assert_eq!(*f, BeatFlag::Flatline, "beat {i}");
        }
        // Runs shorter than the threshold survive.
        let mut beats2 = healthy(20);
        for b in beats2.iter_mut().skip(5).take(3) {
            *b = beat(b.t, 90.0, 60.0);
        }
        let flags2 = assess(&beats2, &ValidityConfig::default());
        assert!(flags2.iter().skip(5).take(3).all(|f| *f != BeatFlag::Flatline));
    }

    #[test]
    fn hypotension_is_not_rejected() {
        // Gradual decline to MAP ~40 must stay valid: rejecting it would
        // destroy the prediction target.
        let mut beats = Vec::new();
        for i in 0..100 {
            let decline = i as f32 * 0.5;
            beats.push(beat(i as f64 * 0.8, 115.0 - decline, 72.0 - decline * 0.9));
        }
        let flags = assess(&beats, &ValidityConfig::default());
        let invalid = flags.iter().filter(|f| **f != BeatFlag::Valid).count();
        assert_eq!(invalid, 0, "{flags:?}");
    }

    #[test]
    fn qc_report_on_synthetic_record() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let cfg = WaveformConfig { record_hours: (4.0, 4.0), ..Default::default() };
        let beats = generate_record(&cfg, &mut rng);
        let flags = assess(&beats, &ValidityConfig::default());
        let report = QcReport::from_flags(&flags);
        assert_eq!(report.total, beats.len());
        // The generator's artifact rate is ~0.4% with flatline amplification;
        // validity should be high but not perfect.
        assert!(report.valid_fraction() > 0.90, "{report:?}");
        assert!(report.valid_fraction() < 1.0, "{report:?}");
        assert!(report.flatline > 0, "{report:?}");
    }
}
