//! Synthetic Arterial Blood Pressure (ABP) waveform generator.
//!
//! MIMIC-III stand-in (see DESIGN.md §Substitutions). The generator
//! produces per-beat blood-pressure records with the properties that drive
//! the paper's results:
//!
//! * **pre-hypotensive drift** — Acute Hypotensive Episodes (AHE) are
//!   preceded by a gradual Mean Arterial Pressure (MAP) decline, so lag
//!   windows immediately before an episode are geometrically close to each
//!   other and far from healthy windows: this is what makes KNN/LSH
//!   prediction work at all;
//! * **heavy class imbalance** — episodes are rare (a few per day of
//!   monitoring), matching the ≥96% negative rates of Table 1;
//! * **realistic mess** — inter-patient baseline variability, slow
//!   mean-reverting drift, respiratory/short-term oscillation, measurement
//!   noise, and invalid-beat artifacts (spikes, dropouts, flatlines) that
//!   the beat-validity layer (`data/beats.rs`, the beatDB stand-in) must
//!   filter out.
//!
//! The model is a per-beat simulation: beat intervals from heart-rate
//! dynamics; per-beat MAP = patient baseline + OU drift + episode profile +
//! oscillation + noise; systolic/diastolic derived from MAP and pulse
//! pressure so validity checks have real structure to verify.

use crate::util::rng::Xoshiro256;

/// One heart beat as produced by the ABP waveform layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Beat {
    /// Beat onset time in seconds from record start.
    pub t: f64,
    /// Systolic blood pressure (mmHg).
    pub sbp: f32,
    /// Diastolic blood pressure (mmHg).
    pub dbp: f32,
}

impl Beat {
    /// Mean arterial pressure via the standard clinical estimate
    /// MAP ≈ DBP + (SBP − DBP) / 3.
    #[inline]
    pub fn map(&self) -> f32 {
        self.dbp + (self.sbp - self.dbp) / 3.0
    }
}

/// Phases of a hypotensive episode overlaid on the baseline pressure.
#[derive(Debug, Clone, Copy, PartialEq)]
enum EpisodePhase {
    None,
    /// Gradual decline toward the episode (the predictive signal).
    Ramp { remaining_s: f64, total_s: f64, depth: f32 },
    /// MAP held below the hypotensive threshold.
    Low { remaining_s: f64, depth: f32 },
    /// Recovery back to baseline.
    Recover { remaining_s: f64, total_s: f64, depth: f32 },
}

/// Generator parameters. Defaults are tuned so the rolling-window pipeline
/// reproduces Table 1's class imbalance (96–98.5% negative).
#[derive(Debug, Clone)]
pub struct WaveformConfig {
    /// Record length (hours) sampled uniformly in this range.
    pub record_hours: (f64, f64),
    /// Mean number of hypotensive episodes per 24h of monitoring.
    pub episodes_per_day: f64,
    /// Pre-episode decline duration (seconds), sampled uniformly.
    pub ramp_s: (f64, f64),
    /// Episode duration (seconds), sampled uniformly.
    pub low_s: (f64, f64),
    /// Recovery duration (seconds), sampled uniformly.
    pub recover_s: (f64, f64),
    /// Mean number of transient non-AHE hypotensive *dips* per 24h: brief
    /// borderline drops (MAP ~58-68) that do NOT meet the AHE definition.
    /// These are the clinically realistic confusers that give the
    /// speed/quality trade-off teeth: aggressive LSH configurations lose
    /// MCC by mistaking dip precursors for episode precursors.
    pub dips_per_day: f64,
    /// Dip duration (seconds), sampled uniformly.
    pub dip_s: (f64, f64),
    /// Probability that a beat is an artifact (spike/dropout).
    pub artifact_prob: f64,
    /// Probability that an artifact starts a flatline run.
    pub flatline_prob: f64,
    /// Per-beat measurement noise std (mmHg).
    pub noise_std: f64,
}

impl Default for WaveformConfig {
    fn default() -> Self {
        Self {
            record_hours: (12.0, 36.0),
            episodes_per_day: 5.5,
            ramp_s: (15.0 * 60.0, 40.0 * 60.0),
            low_s: (30.0 * 60.0, 75.0 * 60.0),
            recover_s: (10.0 * 60.0, 30.0 * 60.0),
            dips_per_day: 10.0,
            dip_s: (3.0 * 60.0, 10.0 * 60.0),
            artifact_prob: 0.004,
            flatline_prob: 0.12,
            noise_std: 3.5,
        }
    }
}

/// Per-patient latent state sampled once per record.
#[derive(Debug, Clone)]
struct PatientState {
    /// Resting MAP baseline (mmHg).
    base_map: f64,
    /// Pulse pressure (SBP − DBP) baseline (mmHg).
    pulse: f64,
    /// Heart rate baseline (bpm).
    hr: f64,
    /// Slow OU process (10–40 min reversion): hemodynamic level wander.
    drift: f64,
    drift_theta: f64,
    drift_sigma: f64,
    /// Fast OU process (1–4 min reversion): within-window *shape*
    /// variation. Without it every lag window is a near-constant vector
    /// at the patient's level and the point cloud degenerates to a line —
    /// real ABP windows differ in trajectory, not just level.
    fast: f64,
    fast_theta: f64,
    fast_sigma: f64,
    /// Respiratory oscillation amplitude (mmHg) and frequency (Hz).
    osc_amp: f64,
    osc_freq: f64,
}

impl PatientState {
    fn sample(rng: &mut Xoshiro256) -> Self {
        Self {
            base_map: (rng.gen_normal(88.0, 9.0)).clamp(72.0, 108.0),
            pulse: (rng.gen_normal(42.0, 7.0)).clamp(25.0, 65.0),
            hr: (rng.gen_normal(80.0, 12.0)).clamp(50.0, 120.0),
            drift: 0.0,
            drift_theta: 1.0 / rng.gen_f64(600.0, 2400.0), // mean-reversion over 10–40 min
            drift_sigma: rng.gen_f64(0.05, 0.20),
            fast: 0.0,
            fast_theta: 1.0 / rng.gen_f64(60.0, 240.0),
            fast_sigma: rng.gen_f64(0.25, 0.70),
            osc_amp: rng.gen_f64(0.8, 2.5),
            osc_freq: rng.gen_f64(0.15, 0.35), // respiratory band
        }
    }
}

/// Generate one patient record of per-beat ABP values.
///
/// Deterministic given `rng` state; fork the rng per record for
/// reproducible corpora.
pub fn generate_record(cfg: &WaveformConfig, rng: &mut Xoshiro256) -> Vec<Beat> {
    let hours = rng.gen_f64(cfg.record_hours.0, cfg.record_hours.1);
    let total_s = hours * 3600.0;
    let mut patient = PatientState::sample(rng);
    let mut beats = Vec::with_capacity((total_s * patient.hr / 60.0) as usize + 16);

    let mut t = 0.0f64;
    let mut phase = EpisodePhase::None;
    // Exponential inter-arrival of episode *ramps*.
    let episode_rate = cfg.episodes_per_day / 86_400.0; // per second
    let mut next_episode_in = sample_exp(rng, episode_rate);
    // Transient dips: ramp down and back over a few minutes, bottoming
    // just ABOVE (or briefly at) the AHE threshold.
    let dip_rate = (cfg.dips_per_day / 86_400.0).max(1e-12);
    let mut next_dip_in = sample_exp(rng, dip_rate);
    let mut dip_left = 0.0f64;
    let mut dip_total = 0.0f64;
    let mut dip_depth = 0.0f64;
    let mut flatline_left = 0usize;
    let mut flatline_value = 0.0f32;

    while t < total_s {
        // --- heart rate / beat interval -----------------------------------
        let hr_jitter = rng.gen_normal(0.0, 2.0);
        let hr = (patient.hr + hr_jitter).clamp(35.0, 180.0);
        let dt = 60.0 / hr;

        // --- episode phase machine -----------------------------------------
        next_episode_in -= dt;
        phase = step_phase(phase, dt);
        if matches!(phase, EpisodePhase::None) && next_episode_in <= 0.0 {
            let ramp = rng.gen_f64(cfg.ramp_s.0, cfg.ramp_s.1);
            // Depth targets an absolute hypotensive MAP level (well below
            // the 60 mmHg AHE threshold) regardless of patient baseline.
            let target_map = rng.gen_f64(44.0, 54.0);
            let depth = (patient.base_map - target_map).max(15.0) as f32;
            phase = EpisodePhase::Ramp { remaining_s: ramp, total_s: ramp, depth };
            next_episode_in = sample_exp(rng, episode_rate)
                + ramp
                + cfg.low_s.1
                + cfg.recover_s.1; // no overlapping episodes
        }
        // Transition Ramp → Low → Recover as phases elapse.
        phase = match phase {
            EpisodePhase::Ramp { remaining_s, .. } if remaining_s <= 0.0 => {
                let low = rng.gen_f64(cfg.low_s.0, cfg.low_s.1);
                let depth = match phase {
                    EpisodePhase::Ramp { depth, .. } => depth,
                    _ => unreachable!(),
                };
                EpisodePhase::Low { remaining_s: low, depth }
            }
            EpisodePhase::Low { remaining_s, depth } if remaining_s <= 0.0 => {
                let _ = remaining_s;
                let rec = rng.gen_f64(cfg.recover_s.0, cfg.recover_s.1);
                EpisodePhase::Recover { remaining_s: rec, total_s: rec, depth }
            }
            EpisodePhase::Recover { remaining_s, .. } if remaining_s <= 0.0 => EpisodePhase::None,
            p => p,
        };

        // --- MAP composition -------------------------------------------------
        // Slow OU drift: dX = -theta X dt + sigma dW (level wander).
        patient.drift += -patient.drift_theta * patient.drift * dt
            + patient.drift_sigma * dt.sqrt() * rng.next_normal();
        patient.drift = patient.drift.clamp(-8.0, 8.0);
        // Fast OU: minute-scale trajectory shape inside lag windows.
        patient.fast += -patient.fast_theta * patient.fast * dt
            + patient.fast_sigma * dt.sqrt() * rng.next_normal();
        patient.fast = patient.fast.clamp(-6.0, 6.0);

        // --- transient dips (only outside real episodes) -------------------
        next_dip_in -= dt;
        if dip_left > 0.0 {
            dip_left -= dt;
        } else if next_dip_in <= 0.0 && matches!(phase, EpisodePhase::None) {
            dip_total = rng.gen_f64(cfg.dip_s.0, cfg.dip_s.1);
            dip_left = dip_total;
            // Bottom lands at MAP ~58-68: borderline, not a sustained AHE.
            let dip_target = rng.gen_f64(58.0, 68.0);
            dip_depth = (patient.base_map - dip_target).max(4.0);
            next_dip_in = sample_exp(rng, dip_rate) + dip_total;
        }
        let dip_offset = if dip_left > 0.0 && dip_total > 0.0 {
            // Smooth down-and-up bump over the dip duration.
            let progress = (1.0 - dip_left / dip_total).clamp(0.0, 1.0);
            dip_depth * (std::f64::consts::PI * progress).sin()
        } else {
            0.0
        };

        let episode_offset = episode_offset(&phase) as f64 + dip_offset;
        let osc = patient.osc_amp
            * (2.0 * std::f64::consts::PI * patient.osc_freq * t).sin();
        let noise = rng.gen_normal(0.0, cfg.noise_std);
        let map = (patient.base_map + patient.drift + patient.fast + osc + noise
            - episode_offset)
            .clamp(20.0, 180.0);

        // --- derive SBP/DBP ---------------------------------------------------
        let pulse = (patient.pulse + rng.gen_normal(0.0, 2.0)).clamp(15.0, 80.0);
        // MAP = DBP + pulse/3  =>  DBP = MAP - pulse/3, SBP = DBP + pulse.
        let dbp = map - pulse / 3.0;
        let sbp = dbp + pulse;

        // --- artifacts ---------------------------------------------------------
        let beat = if flatline_left > 0 {
            flatline_left -= 1;
            Beat { t, sbp: flatline_value, dbp: flatline_value }
        } else if rng.gen_bool(cfg.artifact_prob) {
            if rng.gen_bool(cfg.flatline_prob) {
                flatline_left = rng.gen_range(8, 40) as usize;
                flatline_value = rng.gen_f64(30.0, 120.0) as f32;
                Beat { t, sbp: flatline_value, dbp: flatline_value }
            } else if rng.gen_bool(0.5) {
                // pressure-bag flush / motion spike
                Beat { t, sbp: rng.gen_f64(230.0, 320.0) as f32, dbp: rng.gen_f64(120.0, 200.0) as f32 }
            } else {
                // transducer dropout
                Beat { t, sbp: rng.gen_f64(0.0, 18.0) as f32, dbp: rng.gen_f64(0.0, 9.0) as f32 }
            }
        } else {
            Beat { t, sbp: sbp as f32, dbp: dbp as f32 }
        };
        beats.push(beat);
        t += dt;
    }
    beats
}

fn step_phase(phase: EpisodePhase, dt: f64) -> EpisodePhase {
    match phase {
        EpisodePhase::None => EpisodePhase::None,
        EpisodePhase::Ramp { remaining_s, total_s, depth } => {
            EpisodePhase::Ramp { remaining_s: remaining_s - dt, total_s, depth }
        }
        EpisodePhase::Low { remaining_s, depth } => {
            EpisodePhase::Low { remaining_s: remaining_s - dt, depth }
        }
        EpisodePhase::Recover { remaining_s, total_s, depth } => {
            EpisodePhase::Recover { remaining_s: remaining_s - dt, total_s, depth }
        }
    }
}

/// MAP depression (mmHg) contributed by the episode phase machine.
fn episode_offset(phase: &EpisodePhase) -> f32 {
    match *phase {
        EpisodePhase::None => 0.0,
        // Smooth cosine ramp from 0 to depth — gradual, learnable decline.
        EpisodePhase::Ramp { remaining_s, total_s, depth } => {
            let progress = (1.0 - remaining_s / total_s).clamp(0.0, 1.0);
            let smooth = 0.5 - 0.5 * (std::f64::consts::PI * progress).cos();
            depth * smooth as f32
        }
        EpisodePhase::Low { depth, .. } => depth,
        EpisodePhase::Recover { remaining_s, total_s, depth } => {
            let progress = (1.0 - remaining_s / total_s).clamp(0.0, 1.0);
            let smooth = 0.5 + 0.5 * (std::f64::consts::PI * progress).cos();
            depth * smooth as f32
        }
    }
}

fn sample_exp(rng: &mut Xoshiro256, rate: f64) -> f64 {
    debug_assert!(rate > 0.0);
    -(1.0 - rng.next_f64()).ln() / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(seed: u64) -> Vec<Beat> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let cfg = WaveformConfig { record_hours: (2.0, 2.0), ..Default::default() };
        generate_record(&cfg, &mut rng)
    }

    #[test]
    fn record_is_deterministic() {
        let a = gen(11);
        let b = gen(11);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[100], b[100]);
        assert_eq!(a[a.len() - 1], b[b.len() - 1]);
    }

    #[test]
    fn beat_count_matches_heart_rate_band() {
        let beats = gen(12);
        // 2 hours at 35–180 bpm.
        let lo = 2.0 * 60.0 * 35.0;
        let hi = 2.0 * 60.0 * 180.0;
        assert!((beats.len() as f64) > lo && (beats.len() as f64) < hi);
    }

    #[test]
    fn timestamps_strictly_increase() {
        let beats = gen(13);
        for w in beats.windows(2) {
            assert!(w[1].t > w[0].t);
        }
    }

    #[test]
    fn map_between_dbp_and_sbp_for_normal_beats() {
        let beats = gen(14);
        let mut normal = 0;
        for b in &beats {
            if b.sbp > b.dbp && b.dbp > 20.0 && b.sbp < 220.0 {
                let m = b.map();
                assert!(m > b.dbp && m < b.sbp, "MAP outside [DBP, SBP]: {b:?}");
                normal += 1;
            }
        }
        assert!(normal as f64 > beats.len() as f64 * 0.95);
    }

    #[test]
    fn episodes_actually_depress_map() {
        // Long record with high episode rate must contain sub-60 stretches.
        let mut rng = Xoshiro256::seed_from_u64(15);
        let cfg = WaveformConfig {
            record_hours: (24.0, 24.0),
            episodes_per_day: 5.5,
            ..Default::default()
        };
        let beats = generate_record(&cfg, &mut rng);
        let low = beats.iter().filter(|b| b.map() < 60.0 && b.map() > 25.0).count();
        assert!(
            low as f64 > beats.len() as f64 * 0.02,
            "expected hypotensive stretches, got {low}/{}",
            beats.len()
        );
    }

    #[test]
    fn zero_episode_rate_keeps_map_healthy() {
        let mut rng = Xoshiro256::seed_from_u64(16);
        let cfg = WaveformConfig {
            record_hours: (6.0, 6.0),
            episodes_per_day: 1e-9,
            dips_per_day: 1e-9,
            artifact_prob: 0.0,
            ..Default::default()
        };
        let beats = generate_record(&cfg, &mut rng);
        let low = beats.iter().filter(|b| b.map() < 60.0).count();
        assert!(
            (low as f64) < beats.len() as f64 * 0.01,
            "healthy record has {low} hypotensive beats"
        );
    }

    #[test]
    fn artifacts_present_at_configured_rate() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        let cfg = WaveformConfig {
            record_hours: (8.0, 8.0),
            artifact_prob: 0.01,
            ..Default::default()
        };
        let beats = generate_record(&cfg, &mut rng);
        let weird = beats
            .iter()
            .filter(|b| b.sbp <= b.dbp || b.sbp > 220.0 || b.dbp < 10.0)
            .count();
        // Flatlines amplify the rate; expect at least the base rate.
        assert!(weird as f64 > beats.len() as f64 * 0.005, "weird={weird}");
    }
}
