//! Dataset container + corpus builder.
//!
//! A [`Dataset`] is a dense row-major `n × d` matrix of f32 time-series
//! points plus binary AHE labels — the unit the distributed system shards
//! across nodes. [`build_corpus`] drives the full substrate pipeline
//! (waveform generator → beat validity → rolling windows) until a target
//! number of points is reached, with held-out records providing an
//! out-of-sample query set exactly as the paper's 2000-query test sets.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::beats::ValidityConfig;
use crate::data::waveform::{generate_record, WaveformConfig};
use crate::data::window::{extract_windows, SecondsSeries, WindowSpec};
use crate::util::bytes::{self, CodecError};
use crate::util::rng::Xoshiro256;

const MAGIC: u64 = 0x4453_4C53_4853_4431; // "DSLSHSD1"
const VERSION: u32 = 1;

/// Dense labeled point set.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub name: String,
    /// Point dimensionality (`d`, 30 for the paper's datasets).
    pub dim: usize,
    /// Row-major `len × dim` values (mmHg).
    pub points: Vec<f32>,
    /// AHE-in-condition-window labels.
    pub labels: Vec<bool>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, dim: usize) -> Self {
        Self { name: name.into(), dim, points: Vec::new(), labels: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    pub fn push(&mut self, point: &[f32], label: bool) {
        assert_eq!(point.len(), self.dim);
        self.points.extend_from_slice(point);
        self.labels.push(label);
    }

    /// Fraction of negative (no-AHE) points — Table 1's `%AHE̅` column.
    pub fn pct_negative(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let neg = self.labels.iter().filter(|l| !**l).count();
        neg as f64 / self.len() as f64
    }

    pub fn positives(&self) -> usize {
        self.labels.iter().filter(|l| **l).count()
    }

    /// Contiguous shard `[range.start, range.end)` as an owned dataset —
    /// what the Root sends each node at table-construction time.
    pub fn shard(&self, range: std::ops::Range<usize>) -> Dataset {
        Dataset {
            name: format!("{}[{}..{}]", self.name, range.start, range.end),
            dim: self.dim,
            points: self.points[range.start * self.dim..range.end * self.dim].to_vec(),
            labels: self.labels[range.clone()].to_vec(),
        }
    }

    /// Min/max over every coordinate — the value range the L1 bit-sampling
    /// family quantizes against.
    pub fn value_range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in &self.points {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if lo > hi {
            (0.0, 1.0)
        } else {
            (lo, hi)
        }
    }

    // ---- binary persistence ---------------------------------------------

    pub fn save(&self, path: &Path) -> Result<(), CodecError> {
        let file = std::fs::File::create(path)?;
        let mut w = BufWriter::new(file);
        self.write_to(&mut w)?;
        w.flush()?;
        Ok(())
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<(), CodecError> {
        bytes::write_u64(w, MAGIC)?;
        bytes::write_u32(w, VERSION)?;
        bytes::write_string(w, &self.name)?;
        bytes::write_u64(w, self.dim as u64)?;
        bytes::write_f32_vec(w, &self.points)?;
        bytes::write_bitvec(w, &self.labels)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Dataset, CodecError> {
        let file = std::fs::File::open(path)?;
        let mut r = BufReader::new(file);
        Self::read_from(&mut r)
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Dataset, CodecError> {
        let magic = bytes::read_u64(r)?;
        if magic != MAGIC {
            return Err(CodecError::BadMagic { expected: MAGIC, got: magic });
        }
        let version = bytes::read_u32(r)?;
        if version != VERSION {
            return Err(CodecError::BadVersion(version));
        }
        let name = bytes::read_string(r)?;
        let dim = bytes::read_u64(r)? as usize;
        let points = bytes::read_f32_vec(r)?;
        let labels = bytes::read_bitvec(r)?;
        Ok(Dataset { name, dim, points, labels })
    }
}

/// A corpus: the searchable dataset plus an out-of-sample query set drawn
/// from disjoint patient records (no leakage).
#[derive(Debug, Clone)]
pub struct Corpus {
    pub data: Dataset,
    pub queries: Dataset,
}

/// Corpus builder configuration.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub spec: WindowSpec,
    pub waveform: WaveformConfig,
    pub validity: ValidityConfig,
    /// Stop adding records once the dataset reaches this many points.
    pub target_points: usize,
    /// Out-of-sample query count.
    pub target_queries: usize,
    pub seed: u64,
}

impl CorpusConfig {
    pub fn new(spec: WindowSpec, target_points: usize, target_queries: usize, seed: u64) -> Self {
        Self {
            spec,
            waveform: WaveformConfig::default(),
            validity: ValidityConfig::default(),
            target_points,
            target_queries,
            seed,
        }
    }
}

/// Generate a reproducible corpus by streaming synthetic patient records
/// through the windowing pipeline until the targets are met. Records are
/// never split between data and queries.
pub fn build_corpus(cfg: &CorpusConfig) -> Corpus {
    let mut root = Xoshiro256::seed_from_u64(cfg.seed);
    let mut data = Dataset::new(cfg.spec.name.clone(), cfg.spec.d);
    let mut queries = Dataset::new(format!("{}-queries", cfg.spec.name), cfg.spec.d);
    let mut record_idx = 0u64;
    // Fill the query set first from dedicated records (held out by
    // construction), then the dataset.
    while queries.len() < cfg.target_queries || data.len() < cfg.target_points {
        let mut rng = root.fork(record_idx);
        record_idx += 1;
        let beats = generate_record(&cfg.waveform, &mut rng);
        let series = SecondsSeries::build(&beats, &cfg.validity, cfg.spec.ahe_thresh);
        let pts = extract_windows(&series, &cfg.spec);
        let fill_queries = queries.len() < cfg.target_queries;
        let sink = if fill_queries { &mut queries } else { &mut data };
        for p in pts {
            sink.push(&p.series, p.label);
            if fill_queries && sink.len() >= cfg.target_queries {
                break;
            }
        }
    }
    data.points.truncate(cfg.target_points * data.dim);
    data.labels.truncate(cfg.target_points);
    queries.points.truncate(cfg.target_queries * queries.dim);
    queries.labels.truncate(cfg.target_queries);
    Corpus { data, queries }
}

/// Table 1 row for a built dataset.
#[derive(Debug, Clone)]
pub struct DatasetStats {
    pub name: String,
    pub lag_min: f64,
    pub sub_s: f64,
    pub cond_min: f64,
    pub n: usize,
    pub pct_negative: f64,
}

pub fn stats(spec: &WindowSpec, data: &Dataset) -> DatasetStats {
    DatasetStats {
        name: spec.name.clone(),
        lag_min: spec.lag_min,
        sub_s: spec.subwindow_s(),
        cond_min: spec.cond_min,
        n: data.len(),
        pct_negative: data.pct_negative(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_corpus(seed: u64) -> Corpus {
        let cfg = CorpusConfig::new(WindowSpec::ahe_51_5c(), 3000, 200, seed);
        build_corpus(&cfg)
    }

    #[test]
    fn corpus_hits_targets_exactly() {
        let c = tiny_corpus(1);
        assert_eq!(c.data.len(), 3000);
        assert_eq!(c.queries.len(), 200);
        assert_eq!(c.data.points.len(), 3000 * 30);
        assert_eq!(c.data.dim, 30);
    }

    #[test]
    fn corpus_is_reproducible_and_seed_sensitive() {
        let a = tiny_corpus(7);
        let b = tiny_corpus(7);
        let c = tiny_corpus(8);
        assert_eq!(a.data, b.data);
        assert_eq!(a.queries, b.queries);
        assert_ne!(a.data.points, c.data.points);
    }

    #[test]
    fn class_imbalance_matches_paper_band() {
        let cfg = CorpusConfig::new(WindowSpec::ahe_51_5c(), 20_000, 100, 3);
        let c = build_corpus(&cfg);
        let neg = c.data.pct_negative();
        // Paper: 96.04% for AHE-51-5c. Accept a generous band.
        assert!((0.90..=0.999).contains(&neg), "pct_negative={neg}");
        assert!(c.data.positives() > 0, "need some positive points");
    }

    #[test]
    fn points_are_physiological() {
        let c = tiny_corpus(4);
        let (lo, hi) = c.data.value_range();
        assert!(lo > 15.0 && hi < 185.0, "range=({lo}, {hi})");
    }

    #[test]
    fn shard_roundtrip() {
        let c = tiny_corpus(5);
        let s = c.data.shard(100..200);
        assert_eq!(s.len(), 100);
        assert_eq!(s.point(0), c.data.point(100));
        assert_eq!(s.labels[99], c.data.labels[199]);
    }

    #[test]
    fn save_load_roundtrip() {
        let c = tiny_corpus(6);
        let dir = std::env::temp_dir().join("dslsh_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.dslsh");
        c.data.save(&path).unwrap();
        let loaded = Dataset::load(&path).unwrap();
        assert_eq!(loaded, c.data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_corruption() {
        let c = tiny_corpus(9);
        let mut buf = Vec::new();
        c.data.write_to(&mut buf).unwrap();
        buf[0] ^= 0xFF; // clobber magic
        assert!(matches!(
            Dataset::read_from(&mut std::io::Cursor::new(buf)),
            Err(CodecError::BadMagic { .. })
        ));
    }

    #[test]
    fn stats_row_matches_spec() {
        let c = tiny_corpus(10);
        let spec = WindowSpec::ahe_51_5c();
        let row = stats(&spec, &c.data);
        assert_eq!(row.name, "AHE-51-5c");
        assert!((row.sub_s - 10.0).abs() < 1e-9);
        assert_eq!(row.n, 3000);
    }

    #[test]
    fn queries_and_data_disjoint_by_construction() {
        // Query points should not appear verbatim in the dataset (distinct
        // records => distinct noise draws). Spot-check a few.
        let c = tiny_corpus(11);
        for qi in [0usize, 50, 199] {
            let q = c.queries.point(qi);
            let dup = (0..c.data.len()).any(|i| c.data.point(i) == q);
            assert!(!dup, "query {qi} leaked into dataset");
        }
    }
}
