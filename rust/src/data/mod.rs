//! Data substrate: synthetic ABP waveforms (MIMIC-III stand-in), beat
//! validity (beatDB stand-in), rolling-window extraction, and the dense
//! dataset container the distributed system shards.

pub mod beats;
pub mod dataset;
pub mod waveform;
pub mod window;

pub use dataset::{build_corpus, Corpus, CorpusConfig, Dataset, DatasetStats};
pub use window::WindowSpec;
