//! # DSLSH — Distributed Stratified Locality Sensitive Hashing
//!
//! Production-quality reproduction of *"Distributed Stratified Locality
//! Sensitive Hashing for Critical Event Prediction in the Cloud"*
//! (De Palma, Hemberg & O'Reilly, 2017): a latency-oriented distributed
//! system for approximate K-NN prediction on large medical time-series
//! repositories, evaluated on Acute Hypotensive Episode prediction from
//! Arterial Blood Pressure waveforms.
//!
//! Architecture (see DESIGN.md):
//! * [`data`] — synthetic ABP corpus substrate (MIMIC-III stand-in);
//! * [`lsh`] / [`slsh`] — hash families, tables, stratified index;
//! * [`knn`] / [`metrics`] — top-K, PKNN baseline, voting, MCC;
//! * [`engine`] — pluggable distance scan (native Rust or AOT XLA/PJRT);
//! * [`node`] / [`coordinator`] — the distributed runtime (ν nodes × p
//!   cores, Orchestrator with Root/Forwarder/Reducer, and the
//!   deadline-aware admission queue coalescing independent callers into
//!   shared batch cuts);
//! * [`runtime`] — PJRT artifact loading for the JAX/Pallas hot path;
//! * [`experiments`] — regeneration of every table and figure.

pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod knn;
pub mod lsh;
pub mod metrics;
pub mod net;
pub mod node;
pub mod runtime;
pub mod slsh;
pub mod util;
