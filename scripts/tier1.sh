#!/usr/bin/env bash
# Tier-1 verification: build + full test suite (see ROADMAP.md).
# Usage: scripts/tier1.sh  (run from the repository root; CI entry point)
#
# TIER1_LINT=1 additionally runs the CI lint gate (rustfmt + clippy with
# warnings denied) — off by default so local runs stay fast; the lint job
# in .github/workflows/ci.yml runs the same commands unconditionally.
#
# TIER1_MATRIX=1 additionally builds/tests with --no-default-features so
# the stubbed-`xla` feature split stays buildable both ways (CI sets it).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${TIER1_LINT:-0}" == "1" ]]; then
  cargo fmt --all -- --check
  cargo clippy --all-targets -- -D warnings
fi

cargo build --release
cargo test -q

if [[ "${TIER1_MATRIX:-0}" == "1" ]]; then
  cargo test -q --no-default-features
  # The serving edge's admission-free path must hold without the default
  # features too: the `direct_path` tests drive query_batch_flat straight
  # through the HTTP layer (no admission queue installed).
  cargo test -q --no-default-features --test http_edge direct_path
  # The opt-in AVX2 kernels must stay buildable and parity-clean; on
  # hosts without AVX2 the simd8 tests skip themselves at runtime.
  cargo test -q --features wide-simd --test simd_parity
fi

# Admission layer, explicitly: the scheduling seam every later feature
# (NUMA pinning, multi-probe degradation) plugs into — fail loudly on its
# own. admission_priority holds the deterministic priority-lane/
# pipelining semantics (the PR 2 overrun repro); budget_enforcement the
# deterministic partial/shed/log-only enforcement contract (PR 4);
# streaming_ingest the live-index contracts (seal equivalence, snapshot
# consistency under concurrent inserts, local/TCP insert parity — PR 5);
# fault_tolerance the deterministic replication contract (hedge/backoff
# timing under MockClock, failover bit-identity, synthesized sheds — PR 6).
cargo test -q --test admission_parity
cargo test -q --test admission_priority
cargo test -q --test budget_enforcement
cargo test -q --test streaming_ingest
cargo test -q --test fault_tolerance
# http_edge holds the serving-edge contract (PR 7): hostile-input battery
# over the HTTP framing + JSON schema layer, parser/codec property
# corpora, and the deterministic E2E bit-identity / backpressure /
# readiness suite. The json lib tests pin the hardened parser (depth cap,
# strict numbers, duplicate-key rejection, round-trip property).
cargo test -q --test http_edge
# multiprobe holds the QuerySpec control plane (PR 8): probes=1 + no cap
# bit-identical to the pre-spec paths at every layer (node, cluster,
# admission, wire, HTTP), candidate monotonicity in P, the deterministic
# max_comparisons cap, and typed rejection of invalid specs at the edges.
cargo test -q --test multiprobe
# simd_parity holds the scan-kernel dispatch contract (PR 9): the simd4
# kernel bit-identical to scalar at every entry point (single, batched,
# ranged, cancellable) and through SlshIndex/LiveIndex end to end, plus
# tail-dim property checks against the naive oracle.
cargo test -q --test simd_parity
# observability holds the tracing/metrics contract (PR 10): exact span
# durations under MockClock (no tolerances), traced-vs-untraced result
# bit-identity over a live TCP cluster, slow-ring cause attribution
# (slow/shed/partial/hedged priority), the `/metrics` scrape battery
# (every stats family present, histograms populated), and the per-cause
# counters for otherwise silently-dropped inputs (TCP decode rejects,
# HTTP parser 4xxs). The runtime::hist/runtime::trace lib tests pin the
# power-of-two bucket math, snapshot merge/percentiles, and the tracer's
# ring/pending lifecycle.
cargo test -q --test observability
cargo test -q --lib runtime::hist
cargo test -q --lib runtime::trace
cargo test -q --lib util::json
cargo test -q --lib coordinator::admission
cargo test -q --lib lsh::probe

# The deprecated positional entry points must stay thin shims the crate
# itself no longer calls: everything (examples and benches included) must
# compile warning-clean with deprecation warnings denied. Test binaries
# that exercise the shims on purpose carry #![allow(deprecated)].
RUSTFLAGS="-D warnings" cargo build --release --all-targets

# Bench smoke: asserts the admission-latency, ingest, hedging and
# tradeoff benches produce non-empty CSVs for every scenario (artifact
# plumbing, not timing quality; hedging additionally asserts the hedged
# run hedged; tradeoff that comparisons strictly increase with probes).
# CI uploads results/*.csv.
cargo bench --bench admission_latency -- --smoke
cargo bench --bench ingest -- --smoke
cargo bench --bench hedging -- --smoke
cargo bench --bench tradeoff -- --smoke
# engine_ablation --smoke additionally asserts the simd4 kernel is
# bit-identical to scalar on every (metric, dim) cell and refreshes the
# BENCH_engine.json perf-trajectory record.
cargo bench --bench engine_ablation -- --smoke
# trace_overhead --smoke asserts span collection is bit-identical to the
# untraced path on a live cluster, measures the observability primitives,
# and refreshes the BENCH_observability.json perf-trajectory record.
cargo bench --bench trace_overhead -- --smoke
