#!/usr/bin/env bash
# Tier-1 verification: build + full test suite (see ROADMAP.md).
# Usage: scripts/tier1.sh  (run from the repository root; CI entry point)
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

# Admission layer, explicitly: the scheduling seam every later feature
# (priority classes, NUMA pinning) plugs into — fail loudly on its own.
cargo test -q --test admission_parity
cargo test -q --lib coordinator::admission

# Bench smoke: asserts the admission-latency bench produces a non-empty
# CSV (artifact plumbing, not timing quality).
cargo bench --bench admission_latency -- --smoke
