#!/usr/bin/env bash
# Tier-1 verification: build + full test suite (see ROADMAP.md).
# Usage: scripts/tier1.sh  (run from the repository root; CI entry point)
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
